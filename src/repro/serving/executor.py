"""Discrete-event serving executor: run a solved co-schedule under load.

The DSE (PRs 1-4) answers *what to deploy*; this engine answers *what that
deployment does to requests*: it admits a seeded open-loop trace
(:mod:`.traffic`), batches per-model FIFO queues (max batch size + max
queue delay), and executes batches on servers whose capacity is exactly
what the solved :class:`~repro.core.graph.MultiModelSchedule` granted:

* **partitioned** quotas run concurrently, each on its own chip sub-mesh
  carved from the package's flavor zones
  (:func:`repro.core.regions.flavor_zones`) -- spanning quotas
  (``chip_quota``) get the seam-adjacent slice of each flavor zone, and
  every assignment's stage flavor runs are re-checked against mesh
  coordinates (:func:`repro.core.regions.zigzag_placement`);
* **time-mux** assignments serialize on the whole package inside periodic
  slice windows, with the PR 3 switch cost as dead reload time at each
  slice start (``meta["reload_s"]`` / ``gross_shares``);
* **merged** pipelines interleave at their solved per-model weighted rates
  (``samples_per_beat``).

Service times come from the solved schedule's cost model: a schedule with
``S`` pipeline stages and latency ``L`` for the DSE batch ``m`` is a serial
batch server with beat ``L / (S - 1 + m)`` and service
``(S - 1 + b / samples_per_beat) * beat`` for a ``b``-sample batch -- so a
saturated server reproduces the DSE's throughput figure exactly (batches of
``m`` samples complete every ``L`` seconds).  An optional measured path
(:func:`measure_service_models`) calibrates the service law by timing the
real jitted steps from ``build_multimodel_steps`` instead.

The engine is wall-clock-free and fully deterministic under the trace seed.
"""
from __future__ import annotations

import heapq
import math
from collections import deque
from dataclasses import dataclass, field

from ..core.graph import (
    MM_MERGED,
    MM_PARTITIONED,
    MM_TIME_MUX,
    ModelAssignment,
    MultiModelSchedule,
)
from ..core.hw import HardwareModel
from ..core.regions import check_assignments_placement, flavor_zones
from ..multimodel.quota import package_flavors
from .faults import FaultEvent, FaultInjector
from .metrics import WATERFALL_COMPONENTS, ServingReport, conserve_waterfall, summarize
from .traffic import Request

INF = float("inf")
_EPS = 1e-12

__all__ = [
    "BatchingPolicy",
    "ServiceModel",
    "ServingExecutor",
    "allocate_submeshes",
    "measure_service_models",
    "service_from_assignment",
    "simulate",
]


@dataclass(frozen=True)
class BatchingPolicy:
    """Queue -> batch policy: dispatch when ``max_batch`` samples are
    waiting or the oldest request has queued for ``max_delay_s``.

    ``max_batch`` is in *beats*: a merged-mode model whose
    ``samples_per_beat`` is k dispatches up to ``max_batch * k`` samples
    per batch (k = 1 everywhere else), so a saturated server of any mode
    reproduces its DSE throughput when ``max_batch`` equals the DSE batch.

    ``queue_policy`` selects the dequeue order: ``"fifo"`` (arrival order,
    the whole-request executor's only order) or ``"edf"`` -- earliest SLO
    deadline first, honored by the token-level executor
    (:class:`repro.serving.llm.TokenExecutor`), where a colocated server
    also uses the deadlines to arbitrate prefill batches against decode
    steps.
    """
    max_batch: int = 16
    max_delay_s: float = 2e-3
    max_queue_samples: int | None = None    # admission cap (None = unbounded)
    queue_policy: str = "fifo"

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch {self.max_batch} < 1")
        if self.max_delay_s < 0:
            raise ValueError(f"max_delay_s {self.max_delay_s} < 0")
        if self.queue_policy not in ("fifo", "edf"):
            raise ValueError(f"unknown queue_policy {self.queue_policy!r}")


@dataclass(frozen=True)
class ServiceModel:
    """Batch service law ``overhead + (stages - 1 + b / spb) * beat``."""
    beat: float
    stages: int = 1
    samples_per_beat: float = 1.0
    overhead_s: float = 0.0

    def service_s(self, samples: int) -> float:
        return self.overhead_s + (
            self.stages - 1 + samples / self.samples_per_beat
        ) * self.beat


def service_from_assignment(a: ModelAssignment) -> ServiceModel:
    """Service law of one assignment, from its solved schedule.

    ``beat = latency / (S - 1 + m)`` inverts the pipeline fill model the
    cost evaluator uses, so a server saturated with ``m``-sample batches
    serves exactly the schedule's ``m / latency`` samples/s (times the
    merged-mode ``samples_per_beat`` weighting).
    """
    sched = a.schedule
    if sched.latency <= 0 or sched.latency == INF:
        raise ValueError(f"{a.model}: infeasible schedule cannot serve")
    m = sched.meta.get("m_samples", 1)
    stages = sum(len(seg.clusters) for seg in sched.segments) or 1
    beat = sched.latency / (stages - 1 + m)
    return ServiceModel(beat=beat, stages=stages,
                        samples_per_beat=a.samples_per_beat)


@dataclass
class _Server:
    """One model's execution resource: a serial batch server, optionally
    gated by periodic time-mux availability windows."""
    model: str
    chips: int
    service: ServiceModel
    window: tuple[float, float, float] | None = None   # (offset, span, period)
    free_at: float = 0.0
    down: bool = False          # submesh hit by a failure; dispatch skips it

    def advance(self, t: float, work: float) -> float:
        """Absolute completion time of ``work`` busy-seconds started at
        ``t``, walking this server's availability windows."""
        if self.window is None:
            return t + work
        off, span, period = self.window
        if span <= _EPS:
            raise ValueError(f"{self.model}: zero-width time-mux slice")
        # Walk period indices monotonically (a float-exact boundary time
        # must not re-derive the same index and spin).
        k = math.floor((t - off) / period) - 1
        while True:
            w_start = off + k * period
            w_end = w_start + span
            if w_end - _EPS <= t:
                k += 1
                continue
            cur = max(t, w_start)
            avail = w_end - cur
            if work <= avail + _EPS:
                return cur + min(work, avail)
            work -= avail
            k += 1

    def window_time(self, a: float, b: float) -> float:
        """Seconds of ``[a, b]`` inside availability windows (== ``b - a``
        for always-on servers); the slice-enforcement invariant's oracle."""
        if self.window is None:
            return max(0.0, b - a)
        off, span, period = self.window
        total = 0.0
        k = math.floor((a - off) / period) - 1
        while True:
            w_start = off + k * period
            if w_start >= b:
                return total
            total += max(0.0, min(b, w_start + span) - max(a, w_start))
            k += 1


# ---------------------------------------------------------------------------
# Sub-mesh allocation (quota enforcement on mesh coordinates)
# ---------------------------------------------------------------------------

def allocate_submeshes(
    mm: MultiModelSchedule, hw: HardwareModel
) -> dict[str, dict[str | None, list[tuple[int, int]]]]:
    """Carve each partitioned assignment's chip sub-mesh out of the
    package's flavor zones; returns ``{model: {flavor: coords}}``.

    Single-flavor quotas fill their zone front to back; spanning quotas
    (``chip_quota``) take the seam-adjacent end of the earlier zone and the
    seam-adjacent front of the later one, so a pipeline that crosses the
    flavor seam physically straddles it exactly once.  Overcommitted zones
    raise -- this is the executor's quota-enforcement check.  Time-mux and
    merged deployments share the whole package (every model sees all
    zones).
    """
    counts = package_flavors(hw)
    zones = flavor_zones(counts, hw.mesh_shape, dead=hw.dead_chips)
    if mm.mode != MM_PARTITIONED:
        return {a.model: {f: list(z) for f, z in zones.items()}
                for a in mm.assignments}
    front = {f: 0 for f, _ in counts}
    back = {f: len(zones[f]) for f, _ in counts}
    out: dict[str, dict[str | None, list[tuple[int, int]]]] = {}
    shared: dict[tuple, dict[str | None, list[tuple[int, int]]]] = {}
    for a in mm.assignments:
        # Merged sub-group members share one schedule *and* one resource
        # claim; both must match before they share the carved region.
        share_key = (id(a.schedule), a.chip_type, a.chips,
                     tuple(a.chip_quota or ()))
        prior = shared.get(share_key)
        if prior is not None:
            out[a.model] = prior
            continue
        needs = list(a.chip_quota) if a.chip_quota else [(a.chip_type, a.chips)]
        live = [n for n in needs if n[1] > 0]
        spanning = len(live) > 1
        got: dict[str | None, list[tuple[int, int]]] = {}
        for idx, (f, c) in enumerate(live):
            if f not in zones:
                raise ValueError(f"{a.model}: unknown chip flavor {f!r}")
            zone = zones[f]
            if front[f] + c > back[f]:
                raise ValueError(
                    f"{a.model}: quota overcommits flavor {f!r} "
                    f"({c} chips requested, "
                    f"{back[f] - front[f]} free of {len(zone)})"
                )
            if spanning and idx == 0:
                got[f] = zone[back[f] - c:back[f]]      # seam side (zone end)
                back[f] -= c
            else:
                got[f] = zone[front[f]:front[f] + c]    # zone front
                front[f] += c
        out[a.model] = got
        shared[share_key] = got
    return out


def check_stage_contiguity(mm: MultiModelSchedule, hw: HardwareModel) -> None:
    """Re-check every assignment's per-segment stage flavors against mesh
    coordinates: flavor runs must place contiguously inside their zones
    (raises via :func:`check_assignments_placement` otherwise)."""
    check_assignments_placement(mm.assignments, hw.mesh_shape,
                                package_flavors(hw), dead=hw.dead_chips)


# ---------------------------------------------------------------------------
# Server construction
# ---------------------------------------------------------------------------

def build_servers(
    mm: MultiModelSchedule,
    hw: HardwareModel,
    origin: float = 0.0,
    switch_period_s: float | None = None,
    service_override: dict[str, ServiceModel] | None = None,
) -> dict[str, _Server]:
    """One server per assignment.  Time-mux deployments get periodic
    windows laid out back to back over the scheduling period, each slice's
    useful span starting after its reload time (the PR 3 switch cost)."""
    servers: dict[str, _Server] = {}
    n = len(mm.assignments)
    if mm.mode == MM_TIME_MUX:
        period = switch_period_s or mm.meta.get("switch_period_s", 1.0)
        reloads = mm.meta.get("reload_s", [0.0] * n)
        gross = mm.meta.get("gross_shares") or [
            a.time_share for a in mm.assignments
        ]
        off = 0.0
        for a, g, r in zip(mm.assignments, gross, reloads):
            service = (service_override or {}).get(a.model) \
                or service_from_assignment(a)
            span = a.time_share * period
            servers[a.model] = _Server(
                model=a.model, chips=a.chips, service=service,
                window=(origin + off + r, span, period), free_at=origin,
            )
            off += g * period
        if off > period * (1 + 1e-9):
            raise ValueError(
                f"time-mux slices overflow the period: {off} > {period}"
            )
    else:
        for a in mm.assignments:
            service = (service_override or {}).get(a.model) \
                or service_from_assignment(a)
            servers[a.model] = _Server(model=a.model, chips=a.chips,
                                       service=service, free_at=origin)
    return servers


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

_ARRIVE, _TIMER, _DONE, _CHECK, _FAULT = 0, 1, 2, 3, 4


class ServingExecutor:
    """Event-driven simulation of one deployment under one request trace.

    ``autoscaler`` (optional, see :mod:`.autoscale`) is polled on periodic
    check events; when it returns a re-solved schedule the executor swaps
    the server fleet, charging ``redeploy_s`` (weight reload through DRAM)
    as dead time before the new servers accept work -- in-flight batches
    finish on the old fleet.

    ``faults`` (a :class:`~.faults.FaultInjector` or a list of
    :class:`~.faults.FaultEvent`) injects chip/zone/seam failures: a
    failure marks every server whose submesh intersects the dead chips
    down, spills its in-flight batch back to the queue front, and -- when
    ``fault_resolver`` is set -- triggers a degraded re-solve:
    ``fault_resolver(degraded_hw) -> (MultiModelSchedule | None, info)``
    plans a fresh deployment on the surviving chips (the facade wires it
    through a shared :class:`~repro.api.SolutionCache`, so the dead-chip
    set lands in the problem fingerprint), and the executor swaps fleets
    charging redeploy dead time exactly like an autoscale event.  Repairs
    re-solve back up.  Without a resolver the run degrades statically:
    down models queue until their own chips are repaired.
    """

    def __init__(
        self,
        mm: MultiModelSchedule,
        hw: HardwareModel,
        batching: BatchingPolicy | None = None,
        slos: dict[str, float | None] | None = None,
        autoscaler=None,
        service_override: dict[str, ServiceModel] | None = None,
        switch_period_s: float | None = None,
        reload_s: dict[str, float] | None = None,
        seed: int = 0,
        faults: FaultInjector | list | None = None,
        fault_resolver=None,
        tracer=None,
    ):
        self.mm = mm
        self.hw = hw                     # pristine package (fault baseline)
        # observability: a repro.obs.Tracer fed *simulated* times only --
        # every guard below is `is not None`, so the hot loop pays one
        # comparison when tracing is off (NullTracer normalizes to None)
        self.tracer = tracer if tracer else None
        self._inflight_t0: dict[str, tuple[float, int]] = {}
        self.batching = batching or BatchingPolicy()
        self.slos = slos or {}
        self.autoscaler = autoscaler
        self.service_override = service_override
        self.switch_period_s = switch_period_s
        self.reload_s = reload_s or {}
        self.seed = seed
        self.faults = faults
        self.fault_resolver = fault_resolver
        check_stage_contiguity(mm, hw)
        self.placement = allocate_submeshes(mm, hw)
        self.servers = build_servers(mm, hw, 0.0, switch_period_s,
                                     service_override)
        # per-model accounting (survives autoscale fleet swaps)
        models = list(self.servers)
        self.queues: dict[str, deque[Request]] = {m: deque() for m in models}
        self.queued_samples = {m: 0 for m in models}
        self.arrived = {m: [0, 0] for m in models}
        # drops are attributed to a named cause (strict conservation)
        self.dropped: dict[str, dict[str, list[int]]] = {
            m: {} for m in models
        }
        self.latencies: dict[str, list[float]] = {m: [] for m in models}
        self.req_samples: dict[str, list[int]] = {m: [] for m in models}
        self.batches = {m: 0 for m in models}
        self.busy_s = {m: 0.0 for m in models}
        self.queue_traces: dict[str, list[tuple[float, int]]] = {
            m: [] for m in models
        }
        # per-batch log: (t_start, t_done, work_s, samples, window) -- the
        # slice-enforcement invariant's evidence
        self.batch_log: dict[str, list[tuple]] = {m: [] for m in models}
        # per-request latency waterfalls (Scope Lens): every completed
        # request's latency decomposed into WATERFALL_COMPONENTS, conserved
        # bit-identically against the measured latency
        self.waterfalls: dict[str, list[dict]] = {m: [] for m in models}
        self._acct: dict[int, dict] = {}     # id(request) -> open accounting
        self.redeploys: list[dict] = []
        self._heap: list[tuple] = []
        self._seq = 0
        self._makespan = 0.0
        self._timer_at: dict[str, float] = {}   # pending batch-delay timer
        # fault machinery: the pristine mm/placement are kept so static
        # repairs can rebuild a revived model's original server
        self._mm0 = mm
        self._placement0 = {m: {f: list(z) for f, z in zones.items()}
                            for m, zones in self.placement.items()}
        self._dead: set[tuple[int, int]] = set()
        self._dead_seams: set[tuple[str, str]] = set()
        # epoch fences stale _DONE events of killed servers; _inflight
        # tracks the (single) in-flight batch per server for spilling
        self._epoch = {m: 0 for m in models}
        self._inflight: dict[str, list[Request] | None] = {
            m: None for m in models
        }
        self._down_since: dict[str, float] = {}
        self._downtime = {m: 0.0 for m in models}
        self._pending_recoveries: list[dict] = []
        self.fault_log: list[dict] = []
        self.recoveries: list[dict] = []
        # (t_done, model, samples, latency) for failure-window goodput
        self._completions: list[tuple[float, str, int, float]] = []

    # ------------------------------------------------------------- plumbing
    def _push(self, t: float, kind: int, payload) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (t, kind, self._seq, payload))

    def _trace_queue(self, t: float, model: str) -> None:
        tr = self.queue_traces[model]
        depth = self.queued_samples[model]
        if tr and tr[-1][0] == t:
            tr[-1] = (t, depth)
        else:
            tr.append((t, depth))

    def _drop(self, model: str, cause: str, requests: int,
              samples: int) -> None:
        tally = self.dropped[model].setdefault(cause, [0, 0])
        tally[0] += requests
        tally[1] += samples

    # ------------------------------------------------------------- dispatch
    def _try_dispatch(self, model: str, t: float) -> None:
        q = self.queues[model]
        srv = self.servers[model]
        if srv.down or not q or srv.free_at > t + _EPS:
            return                      # retried when the server frees up
        total = self.queued_samples[model]
        age = t - q[0].t_arrive
        pol = self.batching
        max_batch = max(
            1, round(pol.max_batch * srv.service.samples_per_beat))
        if total < max_batch and age < pol.max_delay_s - _EPS:
            deadline = q[0].t_arrive + pol.max_delay_s
            # one pending timer per model is enough: later arrivals only
            # move the deadline later, and a fired timer re-evaluates
            if self._timer_at.get(model, INF) > deadline + _EPS:
                self._timer_at[model] = deadline
                self._push(deadline, _TIMER, model)
            return
        batch: list[Request] = []
        samples = 0
        while q and samples < max_batch:
            r = q[0]
            if batch and samples + r.samples > max_batch:
                break
            batch.append(q.popleft())
            samples += r.samples
        self.queued_samples[model] -= samples
        self._trace_queue(t, model)
        start = max(t, srv.free_at)
        work = srv.service.service_s(samples)
        # waterfall: older members waited for the newest one (queue_wait);
        # the whole batch then waited for the dispatcher/server (batch_delay)
        t_new = max(self._acct[id(r)]["entry"] for r in batch)
        for r in batch:
            a = self._acct[id(r)]
            a["queue_wait"] += t_new - a["entry"]
            a["batch_delay"] += start - t_new
            a["waits"].append((a["entry"], start))
            a["attempt_start"] = start
            a["work"] = work
        done = srv.advance(start, work)
        srv.free_at = done
        self.busy_s[model] += work
        self.batches[model] += 1
        self.batch_log[model].append((start, done, work, samples, srv.window))
        self._inflight[model] = batch
        if self.tracer is not None:
            self._inflight_t0[model] = (start, samples)
        self._push(done, _DONE, (model, batch, self._epoch[model]))

    # ------------------------------------------------------------ waterfall
    def _finish_waterfall(self, model: str, r, t_done: float,
                          lat: float) -> None:
        """Close a completed request's latency waterfall.

        Components telescope over the request's attempts -- queue_wait
        (waiting for batchmates), batch_delay (dispatcher/server wait),
        service (busy work), stall_time_mux (time-mux window dead time),
        dead_fault (aborted in-flight attempts) -- then queue time spent
        inside redeploy windows is re-attributed to its cause (fault vs
        autoscale re-solve), and the whole thing is conserved bit-exactly
        against the measured latency.
        """
        a = self._acct.pop(id(r), None)
        if a is None:
            return
        service = a.get("work", 0.0)
        stall = (t_done - a.get("attempt_start", t_done)) - service
        comps = {
            "queue_wait": a["queue_wait"],
            "batch_delay": a["batch_delay"],
            "service": service,
            "stall_time_mux": stall,
            "dead_fault": a["dead_fault"],
            "dead_autoscale": 0.0,
        }
        for ev in self.redeploys:
            dur = ev.get("redeploy_s", 0.0)
            t0 = ev.get("t")
            if t0 is None or dur <= 0:
                continue
            key = ("dead_fault" if ev.get("cause") == "fault"
                   else "dead_autoscale")
            for wlo, whi in a["waits"]:
                ov = min(whi, t0 + dur) - max(wlo, t0)
                if ov > 0:
                    comps[key] += ov
                    take = min(ov, comps["queue_wait"])
                    comps["queue_wait"] -= take
                    comps["batch_delay"] -= ov - take
        wf = conserve_waterfall(comps, lat)
        wf["total"] = lat
        self.waterfalls[model].append(wf)

    # ------------------------------------------------------- fleet swapping
    def _current_hw(self) -> HardwareModel:
        """The package as the faults have left it."""
        hw = self.hw
        if self._dead:
            hw = hw.disable_chips(self._dead)
        for a, b in self._dead_seams:
            hw = hw.disable_seam(a, b)
        return hw

    @property
    def degraded(self) -> bool:
        return bool(self._dead or self._dead_seams)

    def _swap_fleet(self, t: float, new_mm: MultiModelSchedule,
                    hw_now: HardwareModel) -> float:
        """Replace the fleet with ``new_mm`` solved on ``hw_now``; charge
        redeploy dead time; returns the new fleet's origin.  Down servers
        come back up (the re-solve placed them on surviving chips);
        surviving in-flight batches drain on their old submeshes first."""
        check_stage_contiguity(new_mm, hw_now)
        redeploy = sum(
            self.reload_s.get(a.model, 0.0) for a in new_mm.assignments
        )
        old = self.servers
        origin = t + redeploy
        self.servers = build_servers(new_mm, hw_now, origin,
                                     self.switch_period_s,
                                     self.service_override)
        if set(self.servers) != set(old):
            raise ValueError(
                f"re-solve changed the model set: {sorted(old)} -> "
                f"{sorted(self.servers)} (re-solves may only move chips)"
            )
        for m, srv in self.servers.items():
            # let in-flight batches drain on the old fleet first (a down
            # server has none: its batch was spilled back to the queue)
            if not old[m].down:
                srv.free_at = max(srv.free_at, old[m].free_at)
        self.mm = new_mm
        self.placement = allocate_submeshes(new_mm, hw_now)
        for m in self.servers:
            self._close_downtime(m, origin)
        for m, srv in self.servers.items():
            # wake every queue when its new server starts accepting work --
            # without this, a model with no in-flight batch and no further
            # arrivals would strand its queued requests forever
            self._push(max(t, srv.free_at), _TIMER, m)
            self._try_dispatch(m, t)
        return origin

    # ------------------------------------------------------------ autoscale
    def _apply_autoscale(self, t: float) -> None:
        hw_now = self._current_hw() if self.degraded else self.hw
        out = self.autoscaler.maybe_resolve(
            t, hw=hw_now if self.degraded else None)
        if self.tracer is not None:
            self.tracer.counter("autoscale_drift", t,
                                round(self.autoscaler.last_drift, 6),
                                group="serving")
        if out is None:
            return
        new_mm, event = out
        if self.tracer is not None:
            self.tracer.instant(
                "autoscale:re-solve", t, group="serving", lane="fleet",
                drift=round(event.get("drift", 0.0), 6),
                cache_hit=event.get("cache_hit"))
        origin = self._swap_fleet(t, new_mm, hw_now)
        event = dict(event, redeploy_s=origin - t)
        self.redeploys.append(event)
        self._settle_recoveries(origin, resolved=True, info=event)

    # --------------------------------------------------------------- faults
    def _close_downtime(self, model: str, t: float) -> None:
        t0 = self._down_since.pop(model, None)
        if t0 is not None:
            self._downtime[model] += max(0.0, t - t0)
        srv = self.servers.get(model)
        if srv is not None:
            srv.down = False

    def _settle_recoveries(self, t: float, resolved: bool,
                           info: dict | None = None) -> None:
        """Close every pending recovery once no server is down."""
        if not self._pending_recoveries:
            return
        if any(s.down for s in self.servers.values()):
            return
        for p in self._pending_recoveries:
            rec = {
                **p,
                "t_recovered": t,
                "ttr_s": t - p["t_fail"],
                "resolved": resolved,
            }
            for k in ("cache_hit", "dse_s", "redeploy_s"):
                if info and k in info:
                    rec[k] = info[k]
            self.recoveries.append(rec)
            if self.tracer is not None:
                self.tracer.instant(
                    "recovered", t, group="serving", lane="faults",
                    target=p.get("target"), ttr_s=round(rec["ttr_s"], 9),
                    resolved=resolved)
        self._pending_recoveries.clear()

    def _seam_blocked(self, zones: dict) -> bool:
        """Does this placement straddle a failed seam?"""
        used = {f for f, coords in zones.items() if coords}
        return any(a in used and b in used for a, b in self._dead_seams)

    def _killed_by(self, model: str) -> bool:
        zones = self.placement[model]
        if any(c in self._dead
               for coords in zones.values() for c in coords):
            return True
        return self._seam_blocked(zones)

    def _spill(self, model: str, t: float) -> int:
        """Kill ``model``'s server: spill the in-flight batch back to the
        queue front (epoch-fencing its pending completion) and mark the
        server down.  Returns the spilled sample count."""
        srv = self.servers[model]
        spilled = 0
        batch = self._inflight[model]
        if batch is not None:
            self._epoch[model] += 1        # fences the stale _DONE
            for r in reversed(batch):
                self.queues[model].appendleft(r)
                # the aborted attempt's in-flight time is fault dead time;
                # the request re-enters the queue at the kill
                a = self._acct[id(r)]
                a["dead_fault"] += t - a["attempt_start"]
                a["entry"] = t
            spilled = sum(r.samples for r in batch)
            self.queued_samples[model] += spilled
            self._inflight[model] = None
            self._trace_queue(t, model)
            if self.tracer is not None:
                # the batch span is truncated at the kill: its server is
                # gone, and the re-dispatched retry opens a fresh span
                b0 = self._inflight_t0.pop(model, None)
                if b0 is not None:
                    self.tracer.complete(
                        "batch (spilled)", b0[0], t, group="serving",
                        lane=model, samples=b0[1], spilled=True)
        if self.tracer is not None:
            self.tracer.instant("kill", t, group="serving", lane=model,
                                spilled_samples=spilled)
        srv.down = True
        srv.free_at = max(srv.free_at, t)
        self._down_since.setdefault(model, t)
        return spilled

    def _revive_static(self, t: float) -> list[str]:
        """Static-degraded repair path: rebuild the original server of
        every down model whose pristine submesh is fully alive again."""
        revived = []
        fresh = None
        for m, srv in list(self.servers.items()):
            if not srv.down or self._killed_by(m):
                continue
            if fresh is None:
                fresh = build_servers(self._mm0, self.hw, 0.0,
                                      self.switch_period_s,
                                      self.service_override)
            nsrv = fresh[m]
            nsrv.free_at = t
            self.servers[m] = nsrv
            self._close_downtime(m, t)
            revived.append(m)
            self._push(t, _TIMER, m)
        return revived

    def _apply_fault(self, t: float, ev: FaultEvent) -> None:
        entry = ev.to_json()
        entry["applied_at"] = t
        if ev.kind == "fail":
            self._dead.update(ev.chips)
            if ev.seam:
                self._dead_seams.add(tuple(sorted(ev.seam)))
            killed, spilled = [], 0
            for m, srv in self.servers.items():
                if not srv.down and self._killed_by(m):
                    spilled += self._spill(m, t)
                    killed.append(m)
            entry.update(killed=killed, spilled_samples=spilled,
                         dead_chips=len(self._dead))
            if self.tracer is not None:
                self.tracer.instant(
                    "fault:fail", t, group="serving", lane="faults",
                    target=ev.target, killed=list(killed),
                    dead_chips=len(self._dead))
            if killed:
                self._pending_recoveries.append(
                    {"t_fail": t, "target": ev.target})
            if killed and self.fault_resolver is not None:
                entry["resolve"] = self._fault_redeploy(t)
        elif ev.kind == "repair":
            changed = (self._dead & set(ev.chips)) or (
                ev.seam and tuple(sorted(ev.seam)) in self._dead_seams)
            if not changed:
                return              # repair of something that never failed
            self._dead.difference_update(ev.chips)
            if ev.seam:
                self._dead_seams.discard(tuple(sorted(ev.seam)))
            if self.tracer is not None:
                self.tracer.instant(
                    "fault:repair", t, group="serving", lane="faults",
                    target=ev.target, dead_chips=len(self._dead))
            if self.fault_resolver is not None:
                # re-solve back up on the (partially) restored package --
                # a full repair re-solves the pristine fingerprint, a
                # SolutionCache hit
                entry["resolve"] = self._fault_redeploy(t)
            else:
                entry["revived"] = self._revive_static(t)
                self._settle_recoveries(t, resolved=False)
            entry.update(dead_chips=len(self._dead))
        self.fault_log.append(entry)

    def _fault_redeploy(self, t: float) -> dict:
        """Ask ``fault_resolver`` for a deployment on the current package;
        swap fleets on success.  An infeasible degraded package leaves the
        down servers down (their queues wait for a repair)."""
        hw_now = self._current_hw()
        new_mm, info = self.fault_resolver(hw_now)
        info = dict(info or {})
        if self.tracer is not None:
            # args stay sim-deterministic: no wall-clock dse_s here
            self.tracer.instant(
                "fault:re-solve", t, group="serving", lane="fleet",
                applied=new_mm is not None and bool(new_mm.assignments),
                cache_hit=info.get("cache_hit"))
        if new_mm is None or not new_mm.assignments:
            info["applied"] = False
            return info
        origin = self._swap_fleet(t, new_mm, hw_now)
        info.update(applied=True, redeploy_s=origin - t,
                    t_serving_again=origin)
        self.redeploys.append(dict(info, t=t, cause="fault"))
        self._settle_recoveries(origin, resolved=True, info=info)
        return info

    # ------------------------------------------------------------------ run
    def run(self, trace: list[Request], horizon_s: float | None = None
            ) -> ServingReport:
        if horizon_s is None:
            horizon_s = trace[-1].t_arrive if trace else 0.0
        for r in trace:
            if r.model not in self.servers:
                raise ValueError(
                    f"request for {r.model!r}: deployment serves "
                    f"{sorted(self.servers)}"
                )
            self._push(r.t_arrive, _ARRIVE, r)
        if self.autoscaler is not None and trace:
            step = self.autoscaler.policy.check_every_s
            t = step
            while t <= horizon_s + _EPS:
                self._push(t, _CHECK, None)
                t += step
        if self.faults is not None:
            events = (self.faults.schedule(horizon_s)
                      if isinstance(self.faults, FaultInjector)
                      else list(self.faults))
            for ev in events:
                self._push(ev.t, _FAULT, ev)
        pol = self.batching
        while self._heap:
            t, kind, _, payload = heapq.heappop(self._heap)
            self._makespan = max(self._makespan, t)
            if kind == _ARRIVE:
                r: Request = payload
                self.arrived[r.model][0] += 1
                self.arrived[r.model][1] += r.samples
                cap = pol.max_queue_samples
                if cap is not None and \
                        self.queued_samples[r.model] + r.samples > cap:
                    self._drop(r.model, "queue_full", 1, r.samples)
                    continue
                if self.autoscaler is not None:
                    self.autoscaler.observe(t, r.model, r.samples)
                self.queues[r.model].append(r)
                self.queued_samples[r.model] += r.samples
                self._acct[id(r)] = {"entry": t, "queue_wait": 0.0,
                                     "batch_delay": 0.0, "dead_fault": 0.0,
                                     "waits": []}
                self._trace_queue(t, r.model)
                self._try_dispatch(r.model, t)
            elif kind == _TIMER:
                if self._timer_at.get(payload, -INF) <= t + _EPS:
                    self._timer_at.pop(payload, None)
                self._try_dispatch(payload, t)
            elif kind == _DONE:
                model, batch, epoch = payload
                if epoch != self._epoch[model]:
                    continue        # batch died with its server (spilled)
                if self._inflight[model] is batch:
                    self._inflight[model] = None
                    if self.tracer is not None:
                        b0 = self._inflight_t0.pop(model, None)
                        if b0 is not None:
                            self.tracer.complete(
                                "batch", b0[0], t, group="serving",
                                lane=model, samples=b0[1])
                for r in batch:
                    lat = t - r.t_arrive
                    self.latencies[model].append(lat)
                    self.req_samples[model].append(r.samples)
                    self._completions.append((t, model, r.samples, lat))
                    self._finish_waterfall(model, r, t, lat)
                self._try_dispatch(model, t)
            elif kind == _CHECK:
                self._apply_autoscale(t)
            elif kind == _FAULT:
                self._apply_fault(t, payload)
        return self._report(horizon_s)

    # --------------------------------------------------------------- report
    def _gated_samples(self, lo: float, hi: float,
                       by_arrival: bool = False) -> int:
        """SLO-satisfying samples completed (or, ``by_arrival``, arrived)
        in ``[lo, hi)``."""
        total = 0
        for t_done, m, s, lat in self._completions:
            t = t_done - lat if by_arrival else t_done
            if lo <= t < hi:
                slo = self.slos.get(m)
                if slo is None or lat <= slo:
                    total += s
        return total

    def _fault_summary(self, makespan: float,
                       horizon_s: float) -> dict | None:
        if self.faults is None and not self.fault_log:
            return None
        span = max(makespan, _EPS)
        downtime = dict(self._downtime)
        for m, t0 in self._down_since.items():
            downtime[m] = downtime.get(m, 0.0) + max(0.0, makespan - t0)
        n_models = max(1, len(self.servers))
        availability = 1.0 - min(
            1.0, sum(downtime.values()) / (n_models * span))
        # failure windows: fault through recovery (or end of run)
        windows = [(r["t_fail"], min(r["t_recovered"], makespan))
                   for r in self.recoveries]
        windows += [(p["t_fail"], makespan)
                    for p in self._pending_recoveries]
        windows.sort()
        merged: list[tuple[float, float]] = []
        for lo, hi in windows:
            if merged and lo <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], hi))
            else:
                merged.append((lo, hi))
        w_span = sum(hi - lo for lo, hi in merged)
        in_w = sum(self._gated_samples(lo, hi) for lo, hi in merged)
        total_good = self._gated_samples(0.0, INF)
        out = {
            "events": len(self.fault_log),
            "log": self.fault_log,
            "recoveries": self.recoveries,
            "unrecovered": len(self._pending_recoveries),
            "availability": availability,
            "downtime_s": {m: round(d, 6) for m, d in downtime.items()},
            "mean_ttr_s": (
                sum(r["ttr_s"] for r in self.recoveries)
                / len(self.recoveries) if self.recoveries else None
            ),
            "failure_window_s": w_span,
            "goodput_in_failure": (in_w / w_span) if w_span > _EPS else None,
            "goodput_outside_failure": (
                (total_good - in_w) / (span - w_span)
                if span - w_span > _EPS else None
            ),
            "redeploy_dead_s": sum(
                e.get("redeploy_s", 0.0) for e in self.redeploys
                if e.get("cause") == "fault"
            ),
        }
        # pre-failure vs post-recovery goodput (the recovery-quality gauge:
        # a recovered fleet should serve within a few percent of the
        # pre-failure rate).  "Post-recovery" starts after the LAST fault
        # activity settles -- the last recovery window, repair event, or
        # fault-driven redeploy -- so a fleet that re-solved onto a
        # degraded package isn't judged at degraded capacity.  Both gauges
        # are by ARRIVAL time: requests arriving after recovery see the
        # recovered fleet's true service, while the failure-window backlog
        # draining late (and SLO-gated out) stays charged to the failure
        # windows, not to the recovered fleet.
        if merged:
            t_first = merged[0][0]
            t_settle = max(
                [merged[-1][1]]
                + [e["applied_at"] for e in self.fault_log
                   if e["kind"] == "repair"]
                + [e.get("t_serving_again",
                         e["t"] + e.get("redeploy_s", 0.0))
                   for e in self.redeploys if e.get("cause") == "fault"]
            )
            out["goodput_pre_fault"] = (
                self._gated_samples(0.0, t_first, by_arrival=True) / t_first
                if t_first > _EPS else None
            )
            # clamped to the arrival horizon: nothing arrives past it
            t_lo, t_hi = t_settle, min(span, horizon_s)
            out["goodput_post_recovery"] = (
                self._gated_samples(t_lo, t_hi, by_arrival=True)
                / (t_hi - t_lo)
                if t_hi - t_lo > _EPS and not self._pending_recoveries
                else None
            )
        else:
            out["goodput_pre_fault"] = None
            out["goodput_post_recovery"] = None
        return out

    def _emit_trace_tracks(self, makespan: float) -> None:
        """Bulk-emit the post-hoc trace tracks: per-model queue-depth
        counter series and redeploy spans on the fleet lane.  A redeploy
        superseded by a later swap is truncated at the swap (the old fleet
        never came up), keeping the lane's spans non-overlapping."""
        tr = self.tracer
        for m in sorted(self.queue_traces):
            for t, depth in self.queue_traces[m]:
                tr.counter(f"queue:{m}", t, depth, group="serving")
            series = tr.metrics.timeseries(f"queue_depth/{m}")
            series.extend(self.queue_traces[m])
        starts = sorted(
            (ev.get("t", 0.0), ev.get("redeploy_s", 0.0),
             ev.get("cause", "autoscale"))
            for ev in self.redeploys
        )
        for i, (t, dur, cause) in enumerate(starts):
            end = t + dur
            if i + 1 < len(starts):
                end = min(end, starts[i + 1][0])
            tr.complete("redeploy", t, max(t, end), group="serving",
                        lane="fleet", cause=cause,
                        redeploy_s=round(dur, 9))
        tr.metrics.counter("serving.batches").set(sum(self.batches.values()))
        tr.metrics.counter("serving.faults").set(len(self.fault_log))
        tr.metrics.counter("serving.recoveries").set(len(self.recoveries))
        tr.metrics.counter("serving.redeploys").set(len(self.redeploys))

    def _report(self, horizon_s: float) -> ServingReport:
        autoscale = None
        if self.autoscaler is not None:
            autoscale = {
                "events": self.redeploys,
                "checks": self.autoscaler.checks,
                "solve_cache": self.autoscaler.cache_stats(),
            }
        mode = self.mm.mode
        meta = {
            "batching": {
                "max_batch": self.batching.max_batch,
                "max_delay_s": self.batching.max_delay_s,
            },
        }
        if self.mm.mode == MM_TIME_MUX:
            meta["switch_period_s"] = (
                self.switch_period_s or self.mm.meta.get("switch_period_s", 1.0)
            )
        busy_chip_s = None
        if self.mm.mode == MM_MERGED:
            # every merged assignment is a slot share of ONE pipeline over
            # the same chips: the pipeline ticks whenever any model has a
            # batch in flight (idle models' slots go empty but the wave
            # still runs), so the package's busy time is the union of the
            # per-model in-flight intervals, not their sum
            pipeline_chips = max(s.chips for s in self.servers.values())
            intervals = sorted(
                (start, done)
                for log in self.batch_log.values()
                for (start, done, *_rest) in log
            )
            union = cur_lo = cur_hi = 0.0
            for lo, hi in intervals:
                if lo > cur_hi:
                    union += cur_hi - cur_lo
                    cur_lo, cur_hi = lo, hi
                else:
                    cur_hi = max(cur_hi, hi)
            union += cur_hi - cur_lo
            busy_chip_s = union * pipeline_chips
            meta["merged_graph"] = self.mm.meta.get("merged_graph")
        makespan = max(self._makespan, horizon_s)
        if self.tracer is not None:
            self._emit_trace_tracks(makespan)
        return summarize(
            mode=mode,
            package=self.hw.name,
            chips=self.hw.chips,
            seed=self.seed,
            horizon_s=horizon_s,
            makespan_s=makespan,
            arrived={m: tuple(v) for m, v in self.arrived.items()},
            dropped={
                m: {cause: tuple(v) for cause, v in causes.items()}
                for m, causes in self.dropped.items()
            },
            latencies=self.latencies,
            request_samples=self.req_samples,
            batches=self.batches,
            busy_s=self.busy_s,
            model_chips={m: s.chips for m, s in self.servers.items()},
            queue_traces=self.queue_traces,
            slos={m: self.slos.get(m) for m in self.servers},
            placement=self.placement,
            autoscale=autoscale,
            meta=meta,
            package_busy_chip_s=busy_chip_s,
            queued_end={
                m: (len(self.queues[m]), self.queued_samples[m])
                for m in self.servers
            },
            faults=self._fault_summary(makespan, horizon_s),
            waterfalls=self.waterfalls,
        )


def simulate(
    mm: MultiModelSchedule,
    hw: HardwareModel,
    trace: list[Request],
    batching: BatchingPolicy | None = None,
    horizon_s: float | None = None,
    **kw,
) -> ServingReport:
    """One-call wrapper: build a :class:`ServingExecutor` and run it."""
    return ServingExecutor(mm, hw, batching=batching, **kw).run(
        trace, horizon_s=horizon_s
    )


# ---------------------------------------------------------------------------
# Measured path: calibrate the service law from the real jitted steps
# ---------------------------------------------------------------------------

def measure_service_models(
    deployment,
    mesh,
    seq_len: int = 16,
    batches: tuple[int, int] = (1, 4),
    iters: int = 3,
) -> dict[str, ServiceModel]:
    """Time the real jitted prefill steps from ``build_multimodel_steps``
    on host devices and fit ``service = overhead + b * beat`` per model.

    The two-point fit at batch sizes ``batches`` separates the fixed
    per-batch overhead from the per-sample slope; the returned models plug
    into ``ServingExecutor(service_override=...)`` so the simulation runs
    on measured instead of modeled service times.
    """
    import time

    import jax
    import jax.numpy as jnp

    from ..models import init_params

    b_lo, b_hi = batches
    if not (0 < b_lo < b_hi):
        raise ValueError(f"need 0 < b_lo < b_hi, got {batches}")
    fleet = deployment.build_steps(mesh, with_decode=False)
    out: dict[str, ServiceModel] = {}
    for cfg in deployment.cfgs:
        prefill = fleet[cfg.name]["prefill"]
        params = init_params(cfg, jax.random.PRNGKey(0))
        timed = {}
        for b in (b_lo, b_hi):
            toks = jnp.ones((b, seq_len), jnp.int32)
            jax.block_until_ready(prefill(params, toks))      # compile
            t0 = time.perf_counter()
            for _ in range(iters):
                jax.block_until_ready(prefill(params, toks))
            timed[b] = (time.perf_counter() - t0) / iters
        beat = max(_EPS, (timed[b_hi] - timed[b_lo]) / (b_hi - b_lo))
        overhead = max(0.0, timed[b_lo] - beat * b_lo)
        out[cfg.name] = ServiceModel(beat=beat, stages=1, overhead_s=overhead)
    return out
