"""Serving executor: run a solved Deployment under synthetic traffic.

The pieces, in data-flow order:

* :mod:`.traffic` -- seeded open-loop request generators (Poisson, bursty
  MMPP, diurnal ramp) producing the merged arrival trace;
* :mod:`.executor` -- the discrete-event engine: per-model FIFO queues, a
  max-batch/max-delay batcher, and servers that enforce exactly the
  resources the co-schedule granted (quota sub-meshes, time-mux slice
  windows with switch cost, merged interleave rates);
* :mod:`.metrics` -- goodput, latency percentiles, queue depths, chip
  utilization, SLO attainment (:class:`~.metrics.ServingReport`);
* :mod:`.autoscale` -- the online re-solve hook (sliding-window mix drift
  -> re-plan through the facade's cached solver);
* :mod:`.faults` -- seeded chip/zone/seam failure injection and the
  degraded-package recovery path (shared with the ft trainer);
* :mod:`.llm` -- token-level LLM serving: prefill/decode phase plans,
  KV-cache-bounded quotas, continuous batching, TTFT/TPOT metrics
  (:class:`~.llm.TokenExecutor` / :class:`~.llm.LLMReport`).

Front doors: :meth:`repro.api.Solution.serve` and
``python -m repro serve`` (``--faults`` for chaos scenarios, ``--llm``
for token-level mixes).
"""
from .autoscale import AutoscalePolicy, Autoscaler
from .faults import FaultEvent, FaultInjector, InjectedFault, parse_faults
from .executor import (
    BatchingPolicy,
    ServiceModel,
    ServingExecutor,
    allocate_submeshes,
    measure_service_models,
    service_from_assignment,
    simulate,
)
from .llm import (
    LLMPlan,
    LLMReport,
    TokenExecutor,
    simulate_tokens,
    solve_phases,
)
from .metrics import ModelMetrics, ServingReport, percentile
from .traffic import (
    MMPP,
    Diurnal,
    Poisson,
    Request,
    TokenLengths,
    phased_trace,
    request_trace,
)

__all__ = [
    "AutoscalePolicy",
    "Autoscaler",
    "BatchingPolicy",
    "Diurnal",
    "FaultEvent",
    "FaultInjector",
    "InjectedFault",
    "LLMPlan",
    "LLMReport",
    "MMPP",
    "ModelMetrics",
    "Poisson",
    "Request",
    "ServiceModel",
    "ServingExecutor",
    "ServingReport",
    "TokenExecutor",
    "TokenLengths",
    "allocate_submeshes",
    "measure_service_models",
    "parse_faults",
    "percentile",
    "phased_trace",
    "request_trace",
    "service_from_assignment",
    "simulate",
    "simulate_tokens",
    "solve_phases",
]
