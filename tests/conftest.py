"""Test bootstrap: make ``src`` importable and gate optional deps.

``hypothesis`` is a declared dev dependency (pyproject.toml), but the
container image used for CI cannot pip-install; when it is absent we install
the deterministic fallback from ``repro._compat.hypothesis_fallback`` under
the ``hypothesis`` name so property-test modules still collect and run as
seeded random sweeps.
"""
from __future__ import annotations

import os
import sys

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

try:
    import hypothesis  # noqa: F401  (real package wins when installed)
except ModuleNotFoundError:
    from repro._compat import hypothesis_fallback as _hf

    _hf.strategies = _hf          # ``from hypothesis import strategies as st``
    sys.modules["hypothesis"] = _hf
    sys.modules["hypothesis.strategies"] = _hf
