"""Pallas TPU flash attention (forward): causal/windowed, GQA, logit softcap.

TPU mapping (DESIGN.md SS3 -- MXU/VMEM adaptation, not a CUDA port):
* grid = (batch, q_heads, Sq/block_q, Skv/block_k); the last axis is
  ``arbitrary`` (sequential) so the online-softmax state lives in VMEM
  scratch across kv steps.
* BlockSpecs stage [block_q, head_dim] / [block_k, head_dim] tiles in VMEM;
  head_dim and block sizes are 128-multiples to fill the MXU's 128x128
  systolic tiles.
* Causal/window masking prunes whole kv blocks via ``pl.when`` (no wasted
  MXU work on fully-masked tiles).
* fp32 running max / sum / accumulator scratch (online softmax).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..._compat.pallas import CompilerParams as _CompilerParams

NEG_INF = -1.0e30


def _fa_kernel(
    q_ref, k_ref, v_ref,            # VMEM tiles
    o_ref,                          # output tile
    m_scr, l_scr, acc_scr,          # scratch: running max/denominator/acc
    *,
    scale: float,
    causal: bool,
    window: int,
    softcap: float,
    block_q: int,
    block_k: int,
    kv_steps: int,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = qi * block_q
    k_lo = kj * block_k

    # Whole-block visibility test: skip fully masked kv tiles.
    run = True
    if causal:
        run = k_lo <= q_lo + block_q - 1
    if window > 0:
        run = jnp.logical_and(run, k_lo + block_k - 1 > q_lo - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                     # [bq, bk]
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(kj == kv_steps - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,      # [B, H, Sq, hd]
    k: jax.Array,      # [B, KV, Skv, hd]
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    assert H % KV == 0
    g = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    kv_steps = Skv // block_k
    scale = 1.0 / math.sqrt(hd)

    grid = (B, H, Sq // block_q, kv_steps)
    kernel = functools.partial(
        _fa_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, kv_steps=kv_steps,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
