"""Hardware models for the Scope cost model.

Two presets:

* :data:`MCM_TABLE_III` -- the paper's evaluation platform (Table III).
  Chiplet: 4x4 PEs, 8 lanes/PE, 8 MACs/lane @ 800 MHz => 1024 MAC/cycle =
  819.2 GMAC/s (1.638 TOPS int8).  64 KB weight buffer per PE (1 MiB/chiplet),
  64 KB global (activation) buffer.  NoP: 2D mesh, 100 GB/s per chiplet,
  1.3 pJ/bit.  DRAM: 100 GB/s total (shared), 128-bit LPDDR5.

* :func:`tpu_v5e` -- the TPU adaptation target (see DESIGN.md SS3):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI, 16 GiB HBM.

The paper regresses NoP/DRAM behaviour from BookSim2/Ramulator2 and compute
from Timeloop; we replace those regressions with bandwidth/peak roofline terms
plus a tiling-quantization utilization model (``eff``), which captures the
paper's two scaling pathologies: NoP overheads and sub-granule
underutilization ("typical utilization below 40% at 64 chiplets").
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace


def eff(dim: float, granule: int) -> float:
    """Tiling efficiency of mapping ``dim`` work onto units of ``granule``.

    eff = dim / (granule * ceil(dim / granule)); 1.0 when dim is a multiple
    of the granule, small when dim << granule (under-filled compute units).
    """
    if dim <= 0:
        return 1e-9
    tiles = math.ceil(dim / granule)
    return dim / (granule * tiles)


@dataclass(frozen=True)
class ChipType:
    """One chiplet flavor of a heterogeneous package (Odema et al. / SCAR).

    ``flops_scale`` / ``nop_bw_scale`` multiply the package's base
    ``flops_per_chip`` / ``nop_bw_per_chip`` (and ``link_bw``); ``chips`` is
    how many chiplets of this flavor the package carries.  The cost model
    evaluates a cluster placed on chips of a single type; the type name is
    folded into the FastCostModel memo key so cached cluster costs never
    leak across flavors.
    """
    name: str
    chips: int
    flops_scale: float = 1.0
    nop_bw_scale: float = 1.0


@dataclass(frozen=True)
class HardwareModel:
    name: str
    chips: int
    mesh_shape: tuple[int, int]
    flops_per_chip: float          # peak FLOP/s (2 x MAC/s at deploy precision)
    nop_bw_per_chip: float         # bytes/s injection bandwidth per chip
    link_bw: float                 # bytes/s of one mesh link (cross-region)
    dram_bw_total: float           # bytes/s package <-> off-chip
    weight_capacity_per_chip: float  # bytes of resident parameter storage
    act_capacity_per_chip: float   # bytes of activation buffering
    m_granule: int                 # activation-dim tiling granule (WSP dim)
    n_granule: int                 # weight-output-dim tiling granule (ISP dim)
    # KV-cache budget for autoregressive decode (bytes per chip).  This is
    # the memory axis the phase DSE trades against: a decode quota of ``c``
    # chips holds at most ``c * kv_bytes_per_chip / kv_seq_bytes`` concurrent
    # sequences, which caps its sustainable batch below the compute optimum
    # when KV runs out before compute does.  0 falls back to the activation
    # buffer (packages without a dedicated KV slice).
    kv_capacity_per_chip: float = 0.0
    # energy (J/unit)
    e_flop: float = 0.0            # J per FLOP (2 flops per MAC)
    e_nop_byte: float = 0.0
    e_dram_byte: float = 0.0
    e_sram_byte: float = 0.0
    # Heterogeneous package: per-region chip flavors.  Empty = homogeneous
    # (every chip is the base flavor described by the fields above).
    region_types: tuple[ChipType, ...] = ()
    # Cross-flavor seam model: the links crossing the boundary between two
    # adjacent regions of *different* flavors.  Default bandwidth is the
    # weaker flavor's link bandwidth (the seam runs at the slower endpoint's
    # SerDes rate), optionally derated by ``seam_bw_scale`` (interposer
    # crossings slower than intra-flavor links).  ``seam_bw_overrides`` pins
    # specific unordered flavor pairs to an absolute bytes/s per link.
    seam_bw_scale: float = 1.0
    seam_bw_overrides: tuple[tuple[str, str, float], ...] = ()
    # Degraded package: mesh coordinates whose chips have failed.  ``chips``
    # and ``region_types`` count the *surviving* chips only; the dead
    # coordinates stay in the field so placement can carve around the holes
    # (and so the frozen value -- hence every problem fingerprint built on
    # it -- distinguishes a degraded package from an intact smaller one).
    dead_chips: tuple[tuple[int, int], ...] = ()

    def with_chips(self, chips: int) -> "HardwareModel":
        side = int(math.sqrt(chips))
        if side * side == chips:
            shape = (side, side)
        else:
            shape = (max(1, chips // max(1, side)), side)
        return replace(self, chips=chips, mesh_shape=shape)

    @property
    def kv_bytes_per_chip(self) -> float:
        """Per-chip KV-cache budget (falls back to the activation buffer)."""
        return self.kv_capacity_per_chip or self.act_capacity_per_chip

    # ------------------------------------------------------- chip flavors
    @property
    def is_heterogeneous(self) -> bool:
        return len(self.region_types) > 0

    def chip_type(self, name: str) -> ChipType:
        for t in self.region_types:
            if t.name == name:
                return t
        raise KeyError(f"{self.name}: unknown chip type {name!r}")

    def typed(self, name: str | None) -> "HardwareModel":
        """The hardware seen by a region of ``name``-flavored chips.

        Scales compute and NoP injection/link bandwidth by the flavor's
        factors; DRAM and buffer capacities stay package-level properties.
        ``None`` (or empty) is the base flavor: returns ``self`` unchanged,
        so homogeneous callers never pay for the indirection.
        """
        if not name:
            return self
        t = self.chip_type(name)
        return replace(
            self,
            name=f"{self.name}:{name}",
            flops_per_chip=self.flops_per_chip * t.flops_scale,
            nop_bw_per_chip=self.nop_bw_per_chip * t.nop_bw_scale,
            link_bw=self.link_bw * t.nop_bw_scale,
            region_types=(),
        )

    def flavor_link_bw(self, name: str | None) -> float:
        """Link bandwidth of one ``name``-flavored chip's mesh links."""
        if not name:
            return self.link_bw
        return self.link_bw * self.chip_type(name).nop_bw_scale

    def seam_link_bw(self, a: str | None, b: str | None) -> float:
        """Bandwidth of one link on the seam between a region of flavor
        ``a`` and an adjacent region of flavor ``b``.

        Same flavor on both sides: the flavor's own link bandwidth
        (homogeneous seam, the pre-mixed-flavor behavior).  Different
        flavors: an explicit override for the pair if one exists, else the
        weaker flavor's link bandwidth times ``seam_bw_scale``.
        """
        if a == b:
            return self.flavor_link_bw(a)
        for x, y, bw in self.seam_bw_overrides:
            if (x == a and y == b) or (x == b and y == a):
                return bw
        return min(self.flavor_link_bw(a), self.flavor_link_bw(b)) * self.seam_bw_scale

    # --------------------------------------------------------- degradation
    def occupied_coords(self) -> list[tuple[int, int]]:
        """Mesh coordinates the package populates (dead chips included):
        the first ``chips + len(dead_chips)`` steps of the zigzag walk."""
        from .regions import zigzag_order

        return zigzag_order(self.mesh_shape)[: self.chips + len(self.dead_chips)]

    def disable_chips(self, dead) -> "HardwareModel":
        """Derive the degraded package after the chips at ``dead`` mesh
        coordinates fail.

        ``chips`` and each flavor's ``region_types`` count shrink to the
        survivors; flavors with no survivor are dropped; the dead
        coordinates accumulate in ``dead_chips`` so the placement layer
        (:func:`repro.core.regions.flavor_zones` with ``dead=``) carves the
        pristine zones minus the holes.  Seam bookkeeping
        (``seam_bw_scale`` / ``seam_bw_overrides``) is untouched -- the
        surviving seam links keep their bandwidth.  Raises when a
        coordinate is outside the occupied mesh or the whole package dies.
        """
        dead = {(int(r), int(c)) for r, c in dead}
        occupied = self.occupied_coords()
        unknown = dead - set(occupied)
        if unknown:
            raise ValueError(
                f"{self.name}: cannot disable unoccupied coords "
                f"{sorted(unknown)} (mesh {self.mesh_shape}, "
                f"{len(occupied)} chips populated)"
            )
        all_dead = dead | set(self.dead_chips)
        alive = [c for c in occupied if c not in all_dead]
        if not alive:
            raise ValueError(f"{self.name}: every chip is dead")
        new_types = self.region_types
        if self.region_types:
            # Pristine flavor zones are consecutive slices of the walk; an
            # already-degraded package re-derives them from the current
            # (alive) counts by skipping its current dead coords.  Interior
            # dead coords at a zone boundary are attributed to the later
            # zone; the trailing run goes to the last zone -- alive counts,
            # the only observable, are identical either way.
            cur_dead = set(self.dead_chips)
            spans, pos = [], 0
            for i, t in enumerate(self.region_types):
                if i == len(self.region_types) - 1:
                    spans.append(len(occupied) - pos)
                    break
                n_alive = n_total = 0
                while n_alive < t.chips:
                    if occupied[pos + n_total] not in cur_dead:
                        n_alive += 1
                    n_total += 1
                spans.append(n_total)
                pos += n_total
            shrunk, pos = [], 0
            for t, span in zip(self.region_types, spans):
                zone = occupied[pos : pos + span]
                pos += span
                n = sum(1 for c in zone if c not in all_dead)
                if n:
                    shrunk.append(replace(t, chips=n))
            new_types = tuple(shrunk)
        hw = replace(
            self,
            chips=len(alive),
            region_types=new_types,
            dead_chips=tuple(sorted(all_dead)),
        )
        validate_region_types(hw)
        return hw

    def disable_seam(self, a: str, b: str,
                     bw: float = 1.0) -> "HardwareModel":
        """Fail the interconnect seam between flavors ``a`` and ``b``:
        pins the pair's per-link bandwidth to ``bw`` (default 1 byte/s --
        effectively unusable, so a re-solve routes around it) via
        ``seam_bw_overrides``; chips on both sides stay alive."""
        for n in (a, b):
            self.chip_type(n)       # raises on unknown flavors
        overrides = tuple(
            (x, y, w) for x, y, w in self.seam_bw_overrides
            if {x, y} != {a, b}
        ) + ((a, b, bw),)
        return replace(self, seam_bw_overrides=overrides)


def validate_region_types(hw: HardwareModel) -> None:
    if not hw.region_types:
        return
    names = [t.name for t in hw.region_types]
    assert len(set(names)) == len(names), f"duplicate chip types: {names}"
    assert all(n for n in names), "chip types need non-empty names"
    total = sum(t.chips for t in hw.region_types)
    assert total == hw.chips, (
        f"{hw.name}: region_types cover {total} != {hw.chips} chips"
    )
    assert hw.seam_bw_scale > 0, f"seam_bw_scale {hw.seam_bw_scale} <= 0"
    for x, y, bw in hw.seam_bw_overrides:
        assert bw > 0, f"seam override {x}<->{y}: bandwidth {bw} <= 0"
        for n in (x, y):
            assert any(t.name == n for t in hw.region_types), (
                f"seam override names unknown chip type {n!r}"
            )


def mcm_table_iii(chips: int = 256) -> HardwareModel:
    macs_per_s = 16 * 8 * 8 * 800e6          # 4x4 PEs x 8 lanes x 8 MACs @ 800MHz
    return HardwareModel(
        name=f"mcm{chips}",
        chips=chips,
        mesh_shape=(int(math.sqrt(chips)), int(math.sqrt(chips)))
        if int(math.sqrt(chips)) ** 2 == chips
        else (1, chips),
        flops_per_chip=2.0 * macs_per_s,      # 1.638 TOPS int8
        nop_bw_per_chip=100e9,
        link_bw=100e9,                        # paper: 100 GB/s per chiplet
        dram_bw_total=100e9,
        weight_capacity_per_chip=16 * 64 * 1024,   # 16 PEs x 64 KB = 1 MiB
        act_capacity_per_chip=64 * 1024,           # 64 KB global buffer
        kv_capacity_per_chip=32 * 2**20,           # LPDDR KV slice per chiplet
        m_granule=1,                          # row-stripe quantization (rows/chip)
        n_granule=16,                         # out-channels spread across 16 PEs;
                                              # lanes/MACs consume the reduction dim
        e_flop=0.2e-12 / 2.0,                 # 0.2 pJ per 8-bit MAC
        e_nop_byte=1.3e-12 * 8,               # 1.3 pJ/bit
        e_dram_byte=8e-12 * 8,                # LPDDR5 ~8 pJ/bit (documented estimate)
        e_sram_byte=0.6e-12 * 8,              # 28nm SRAM ~0.6 pJ/bit (documented estimate)
    )


def tpu_v5e(chips: int = 256, mesh_shape: tuple[int, int] = (16, 16)) -> HardwareModel:
    return HardwareModel(
        name=f"tpu_v5e_{chips}",
        chips=chips,
        mesh_shape=mesh_shape,
        flops_per_chip=197e12,                # bf16 peak
        nop_bw_per_chip=4 * 50e9,             # 4 ICI links per chip (2D torus)
        link_bw=50e9,
        dram_bw_total=819e9 * chips * 0.05,   # host->HBM staging; prep phase only
        weight_capacity_per_chip=16 * 2**30,  # 16 GiB HBM
        act_capacity_per_chip=16 * 2**30,     # HBM is unified; VMEM modeled in kernels
        m_granule=8,                          # sublane granularity
        n_granule=128,                        # MXU lane width
        e_flop=0.25e-12,                      # ~0.25 pJ/FLOP bf16 (documented estimate)
        e_nop_byte=0.3e-12 * 8,
        e_dram_byte=6e-12 * 8,
        e_sram_byte=0.1e-12 * 8,
    )


def mcm_hetero(
    chips: int = 64,
    big_fraction: float = 0.5,
    big_flops_scale: float = 1.0,
    little_flops_scale: float = 0.5,
    little_nop_scale: float = 0.75,
) -> HardwareModel:
    """Table III package with a big/little chiplet split (hetero extension).

    ``big`` chips are the base Table III chiplet; ``little`` chips trade
    compute (and some NoP bandwidth) for area/power, the setting of the
    multi-model co-scheduling papers (Odema et al., SCAR).
    """
    big = int(round(chips * big_fraction))
    big = min(max(big, 1), chips - 1)
    hw = replace(
        mcm_table_iii(chips),
        name=f"mcm{chips}_hetero",
        region_types=(
            ChipType("big", big, flops_scale=big_flops_scale),
            ChipType("little", chips - big,
                     flops_scale=little_flops_scale,
                     nop_bw_scale=little_nop_scale),
        ),
    )
    validate_region_types(hw)
    return hw


def mcm_hetero3(
    chips: int = 48,
    flops_scales: tuple[float, float, float] = (1.0, 0.6, 0.3),
    nop_scales: tuple[float, float, float] = (1.0, 0.85, 0.7),
) -> HardwareModel:
    """Table III package with three chiplet flavors (big / mid / little).

    Exercises the 3+-flavor regime end to end: the per-cluster mixed DSE
    handles any flavor count, and the multimodel spanning-quota enumeration
    scores k-flavor budget tuples against F-dimensional mixed envelopes
    (``quota.search_partitioned_mixed``), so no single-flavor fallback is
    involved.
    """
    third = chips // 3
    counts = (chips - 2 * third, third, third)
    hw = replace(
        mcm_table_iii(chips),
        name=f"mcm{chips}_hetero3",
        region_types=tuple(
            ChipType(name, n, flops_scale=f, nop_bw_scale=b)
            for name, n, f, b in zip(
                ("big", "mid", "little"), counts, flops_scales, nop_scales
            )
        ),
    )
    validate_region_types(hw)
    return hw


# Convenience preset registry used by benchmarks / CLI.
PRESETS = {
    "mcm16": lambda: mcm_table_iii(16),
    "mcm64": lambda: mcm_table_iii(64),
    "mcm256": lambda: mcm_table_iii(256),
    "mcm1024": lambda: mcm_table_iii(1024),
    "tpu_v5e_256": lambda: tpu_v5e(256, (16, 16)),
    "tpu_v5e_512": lambda: tpu_v5e(512, (16, 32)),
    "mcm64_hetero": lambda: mcm_hetero(64),
    "mcm16_hetero": lambda: mcm_hetero(16),
    "mcm48_hetero3": lambda: mcm_hetero3(48),
}


def get_hw(name: str) -> HardwareModel:
    if name in PRESETS:
        return PRESETS[name]()
    if name.startswith("mcm"):
        return mcm_table_iii(int(name[3:]))
    raise KeyError(name)
