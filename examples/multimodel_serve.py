"""Multi-model co-scheduling walkthrough: mixed traffic on one MCM package.

Everything goes through the solver facade (``repro.scope``): schedule a
3-model mix (weighted traffic) onto a 64-chiplet package
(``solve`` auto-selects the ``coschedule`` strategy), compare it against
the two static baselines by switching the strategy, then show the same
subsystem on a heterogeneous big/little package -- including mixed-flavor
quotas, where one model's pipeline spans both flavors -- and finally drive
a mixed-flavor plan end to end through the runtime bridge
(``Solution.deploy`` -> ``Deployment.build_steps``) on a host-device mesh.

    PYTHONPATH=src python examples/multimodel_serve.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from repro import scope

# Traffic mix: resnet50 gets 2x the request rate of the small models.
MIX = "resnet50:2,resnet18:1,alexnet:1"

prob = scope.problem(MIX, "mcm64", m_samples=16)
print(f"mix {MIX} on mcm64\n")
co = scope.solve(prob)
for line in co.describe():
    print(line)
print(f"  modes searched: "
      f"{ {k: round(v) for k, v in co.diagnostics['mode_rates'].items()} }")

print("\nstatic baselines:")
for name in ("equal-split", "time-mux"):
    b = scope.solve(prob.with_options(strategy=name))
    print(f"  {name:12s} {b.weighted_throughput:9.1f} samples/s "
          f"({co.weighted_throughput / b.weighted_throughput:.2f}x behind)")

# --- serving: run the deployment under load ------------------------------
# The serving executor replays a seeded open-loop trace against the solved
# schedule: per-model queues + batching, quota sub-meshes enforced, service
# times from the solved cost model.  Offered load is 90% of solved
# capacity; bursty (MMPP) traffic for resnet50.
from repro.serving import MMPP, Poisson

mm = co.as_multimodel()
lam = mm.mix_rate * 0.9
traffic = {
    a.model: (MMPP(rate_low=0.5 * lam * a.weight,
                   rate_high=2.0 * lam * a.weight)
              if a.model == "resnet50" else Poisson(lam * a.weight))
    for a in mm.assignments
}
report = co.serve(traffic=traffic, horizon_s=0.5, seed=0)
print("\nserving the co-schedule (90% load, resnet50 bursty):")
for line in report.describe():
    print(line)

# --- heterogeneous package: quotas are drawn per chip flavor -------------
# Mixed-flavor quotas are searched too: a model's pipeline may start on big
# chips and finish on little ones, crossing the flavor seam
# (hw.seam_link_bw) exactly once -- look for `quota=AxBig+BxLittle` below,
# and the validator's seam accounting in the diagnostics.
co2 = scope.solve(scope.problem("resnet50:4,resnet18:1", "mcm64_hetero"))
print(f"\nmix resnet50:4,resnet18:1 on {co2.hw.name} "
      f"({', '.join(f'{t.chips}x{t.name}' for t in co2.hw.region_types)})")
for line in co2.describe():
    print(line)
print(f"  modes searched: "
      f"{ {k: round(v) for k, v in co2.diagnostics['mode_rates'].items()} }")
print(f"  seam crossings per model: {co2.diagnostics['seam_crossings']}")

# --- runtime bridge: a mixed-flavor plan end to end ----------------------
# Co-schedule two tiny LM configs onto a heterogeneous 8-chip model axis,
# then build their jitted serving steps on a shared host-device mesh.  The
# workload carries the ModelConfigs, so solve -> deploy is two lines; each
# plan records which chip flavor serves which pipeline stage
# (plan.stage_chip_types); a mixed-flavor assignment itemizes its
# per-flavor chips in meta["chip_quota"].
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.hw import ChipType, tpu_v5e
from repro.launch.mesh import make_mesh
from repro.models import init_params

MODEL_AXIS = 8
hw3 = replace(
    tpu_v5e(MODEL_AXIS, (1, MODEL_AXIS)),
    name=f"tpu_v5e_{MODEL_AXIS}_hetero",
    region_types=(
        ChipType("big", 4),
        ChipType("little", 4, flops_scale=0.5, nop_bw_scale=0.75),
    ),
)
cfgs = [get_smoke_config("granite-3-8b"), get_smoke_config("granite-20b")]
sol = scope.solve(scope.problem(
    scope.WorkloadSpec.lm(cfgs, seq_len=64, weights=[2.0, 1.0]), hw3,
    m_samples=8, include_merged=False,
))
dep = sol.deploy(global_batch=8, mesh_axes=("data", "model"))
print(f"\nruntime bridge on {hw3.name} (4xbig + 4xlittle):")
for line in sol.describe():
    print(line)
for name, plan in dep.plans.items():
    print(f"  {name}: p1={plan.p1} p2={plan.p2} "
          f"stages={[(lo, hi, t, c) for lo, hi, t, c in plan.stage_chip_types]}")

mesh = make_mesh((1, len(jax.devices())), ("data", "model"))
fleet = dep.build_steps(mesh, with_decode=False)
for cfg in cfgs:
    prefill = fleet[cfg.name]["prefill"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.ones((2, 16), jnp.int32)
    logits = prefill(params, toks)
    print(f"  {cfg.name}: prefill logits {logits.shape} on {mesh.shape}")
