"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
expert d_ff=8192, vocab=202048, MoE 128 experts top-1, dense/MoE
interleaved (every 2nd layer routed) [hf:meta-llama/Llama-4; unverified].

~396B total / ~14B active parameters with this layout (the published
"17B active" includes a shared expert per MoE layer, which we fold into
the alternating dense FFN -- documented approximation).

Production note: AdamW state for 400B params does not fit 256 chips;
this config selects the factored optimizer (adafactor) -- see
EXPERIMENTS.md SSPerf.
"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    moe=MoEConfig(n_experts=128, top_k=1, every=2, capacity_factor=1.25),
    ffn_gated=True,
    rope_theta=500_000.0,
    optimizer="adafactor",
    accum_steps=4,
)
