"""Token-level LLM serving tests: KV accounting, KV-bounded curves
(parity + strict lowering), phase DSE, the TokenExecutor (conservation,
KV-bound enforcement, continuous vs static batching, EDF no-starvation),
and the llm-phase facade plumbing."""
from __future__ import annotations

import math

import pytest

from repro import scope
from repro.configs import get_smoke_config
from repro.core.fastcost import FastCostModel
from repro.core.hw import get_hw
from repro.core.workloads.lm import lm_graph
from repro.multimodel.curves import kv_bound_curve, service_law, throughput_curve
from repro.serving import (
    BatchingPolicy,
    TokenLengths,
    request_trace,
    simulate_tokens,
)
from repro.serving.llm import (
    kv_capacity_bytes,
    kv_seq_bytes,
    max_concurrent_seqs,
    solve_phases,
)

SEQ = 128
OUT = 32.0


@pytest.fixture(scope="module")
def cfgs():
    return [get_smoke_config("gemma2-9b"), get_smoke_config("granite-3-8b")]


@pytest.fixture(scope="module")
def llm_sol(cfgs):
    wl = scope.WorkloadSpec.lm(cfgs, SEQ, [2.0, 1.0])
    prob = scope.problem(wl, "mcm16", strategy="llm-phase",
                         output_tokens=OUT, m_samples=8)
    sol = scope.solve(prob)
    assert sol.feasible
    return sol


@pytest.fixture(scope="module")
def token_trace(llm_sol):
    traffic, horizon = llm_sol.offered_traffic(0.8, 400)
    lengths = TokenLengths(prompt_mean=SEQ, output_mean=OUT, output_max=256)
    return request_trace(traffic, horizon, seed=3, lengths=lengths), horizon


# ---------------------------------------------------------------- kv model

def test_kv_seq_bytes_families(cfgs):
    gemma, granite = cfgs
    for cfg in cfgs:
        assert kv_seq_bytes(cfg, SEQ) > 0
    # attention KV grows with context; gemma2-smoke mixes local layers whose
    # window caps their share, so growth is sublinear but strictly positive
    assert kv_seq_bytes(granite, 2 * SEQ) == 2 * kv_seq_bytes(granite, SEQ)
    assert kv_seq_bytes(gemma, 2 * SEQ) > kv_seq_bytes(gemma, SEQ)


def test_kv_capacity_and_max_seqs(cfgs):
    hw = get_hw("mcm16")
    assert hw.kv_bytes_per_chip > 0
    cap = kv_capacity_bytes(hw, 4)
    assert cap == 4 * hw.kv_bytes_per_chip
    k = max_concurrent_seqs(hw, 4, cfgs[0], SEQ)
    assert k == int(cap // kv_seq_bytes(cfgs[0], SEQ)) and k > 0


# ------------------------------------------------------- kv-bounded curves

@pytest.fixture(scope="module")
def decode_curve(cfgs):
    hw = get_hw("mcm16")
    cost = FastCostModel(hw, m_samples=8)
    g = lm_graph(cfgs[0], SEQ, decode=True)
    return throughput_curve(cost, g, hw.chips)


def test_kv_bound_parity_infinite_capacity(decode_curve):
    """Satellite: with KV never binding, the bounded curve is bit-identical
    to the unbounded one (the same CurvePoint objects)."""
    sb = 1024.0
    bounded = kv_bound_curve(decode_curve, sb, float("inf"))
    assert set(bounded.points) == set(decode_curve.points)
    for c, pt in decode_curve.points.items():
        assert bounded.points[c] is pt
    # zero per-sequence state short-circuits to the identical curve object
    assert kv_bound_curve(decode_curve, 0.0, 1.0) is decode_curve


def test_kv_bound_lowers_envelope(decode_curve, cfgs):
    """Satellite: a tight capacity strictly lowers the decode envelope and
    flattens it (memory-bound rate, not compute-bound)."""
    sb = kv_seq_bytes(cfgs[0], SEQ)
    # capacity for ~2 sequences per chip: K < m_samples=8 on small quotas
    bounded = kv_bound_curve(decode_curve, sb, 2 * sb)
    lowered = 0
    for c, pt in decode_curve.points.items():
        bpt = bounded.points[c]
        assert bpt.throughput <= pt.throughput + 1e-12
        if bpt.throughput < pt.throughput:
            lowered += 1
            stages, beat = service_law(pt.schedule)
            k = bpt.max_seqs
            assert k == int(2 * c)  # floor(c * 2*sb / sb)
            assert bpt.throughput == pytest.approx(
                k / ((stages - 1 + k) * beat))
    assert lowered > 0
    # infeasibly small capacity: every point is memory-infeasible
    n = max(decode_curve.points)
    cap = sb / (2 * n)
    starved = kv_bound_curve(decode_curve, sb, cap)
    pts = list(starved.points.values())
    assert pts and all(p.max_seqs == 0 and p.throughput == 0.0
                       for p in pts if p.chips * cap < sb)


# ------------------------------------------------------------ phase DSE

def test_solve_phases_modes(cfgs):
    hw = get_hw("mcm16")
    cost = FastCostModel(hw, m_samples=8)
    plan, diag = solve_phases(cfgs, [2.0, 1.0], hw, cost, seq_len=SEQ,
                              output_tokens=OUT, m_samples=8)
    assert plan is not None and plan.mix_rate > 0
    assert set(diag["plans"]) == {"disaggregated", "colocated"}
    assert plan.mix_rate == max(diag["mode_rates"].values())
    for mode, p in diag["plans"].items():
        if p is None:
            continue
        used = sum(
            (a.prefill_chips if mode == "colocated"
             else a.prefill_chips + a.decode_chips)
            for a in p.assignments
        )
        assert 0 < used <= hw.chips
        for a in p.assignments:
            assert a.kv_capacity_bytes > 0 and a.max_seqs > 0
            assert a.prefill_schedule is not None
            assert a.decode_schedule is not None  # OUT > 1
    # pinning a mode returns that mode
    p_col, _ = solve_phases(cfgs, [2.0, 1.0], hw, cost, seq_len=SEQ,
                            output_tokens=OUT, mode="colocated", m_samples=8)
    assert p_col.mode == "colocated"
    with pytest.raises(ValueError):
        solve_phases(cfgs, [2.0, 1.0], hw, cost, seq_len=SEQ, mode="bogus")


# -------------------------------------------------------- token executor

def test_token_executor_conservation_and_kv_bound(llm_sol, token_trace):
    trace, horizon = token_trace
    rep = llm_sol.serve(trace=trace, horizon_s=horizon, seed=3,
                        ttft_slo=0.05, tpot_slo=0.005)
    assert rep.conserved
    assert rep.total_arrived == len(trace)
    assert rep.total_completed > 0
    for m in rep.per_model.values():
        # occupancy never exceeds the searched KV bound
        assert m.kv_peak_bytes <= m.kv_capacity_bytes + 1e-6
        assert m.ttft_p95_s >= 0 and m.tpot_p95_s >= 0
    assert rep.metrics.counter("llm.admitted_midbatch").value == \
        rep.admitted_midbatch


def test_continuous_beats_static(llm_sol, token_trace):
    """Continuous batching admits mid-batch and never loses to the static
    whole-request baseline on the identical trace."""
    trace, horizon = token_trace
    kw = dict(trace=trace, horizon_s=horizon, seed=3,
              ttft_slo=0.05, tpot_slo=0.005)
    cont = llm_sol.serve(**kw)
    stat = llm_sol.serve(static_batching=True, **kw)
    assert cont.batching == "continuous" and stat.batching == "static"
    assert cont.admitted_midbatch > 0
    assert stat.admitted_midbatch == 0
    assert stat.conserved
    assert cont.token_goodput >= stat.token_goodput


def test_token_executor_deterministic(llm_sol, token_trace):
    trace, horizon = token_trace
    kw = dict(trace=trace, horizon_s=horizon, seed=3, ttft_slo=0.05,
              tpot_slo=0.005)
    a = llm_sol.serve(**kw).to_json()
    b = llm_sol.serve(**kw).to_json()
    assert a == b


def test_static_replay_of_other_mode(llm_sol, token_trace):
    """The losing deployment mode replays on the identical trace via
    serve(plan=...) -- the bench's baseline path."""
    trace, horizon = token_trace
    plans = llm_sol.diagnostics["plans"]
    other = plans["colocated" if llm_sol.llm.mode == "disaggregated"
                  else "disaggregated"]
    assert other is not None
    rep = llm_sol.serve(plan=other, static_batching=True, trace=trace,
                        horizon_s=horizon, seed=3)
    assert rep.mode == other.mode and rep.conserved


def test_edf_no_starvation(cfgs):
    """Satellite regression: EDF reorder never starves a model -- every
    request still completes (or is accounted) under strict conservation,
    for both deployment modes."""
    wl = scope.WorkloadSpec.lm(cfgs, SEQ, [2.0, 1.0])
    for mode in ("colocated", "disaggregated"):
        prob = scope.problem(wl, "mcm16", strategy="llm-phase",
                             output_tokens=OUT, phase_mode=mode, m_samples=8)
        sol = scope.solve(prob)
        assert sol.feasible and sol.llm.mode == mode
        traffic, horizon = sol.offered_traffic(0.9, 300)
        lengths = TokenLengths(prompt_mean=SEQ, output_mean=OUT)
        trace = request_trace(traffic, horizon, seed=5, lengths=lengths)
        # mixed SLO tightness across models: EDF favors the tight one but
        # must not starve the loose one
        rep = sol.serve(trace=trace, horizon_s=horizon, seed=5,
                        queue_policy="edf",
                        ttft_slo={cfgs[0].name + "-smoke": 0.001,
                                  cfgs[1].name + "-smoke": 1.0},
                        tpot_slo=0.005)
        assert rep.conserved
        assert rep.meta["queue_policy"] == "edf"
        for m in rep.per_model.values():
            # drained run: nothing starved in queue forever
            assert m.completed_requests + m.dropped_requests == \
                m.arrived_requests
            assert m.completed_requests > 0


def test_edf_matches_fifo_population(llm_sol, token_trace):
    """EDF reorders but conserves: same completed-request population size
    as FIFO on the identical trace."""
    trace, horizon = token_trace
    kw = dict(trace=trace, horizon_s=horizon, seed=3, ttft_slo=0.02,
              tpot_slo=0.005)
    fifo = llm_sol.serve(queue_policy="fifo", **kw)
    edf = llm_sol.serve(queue_policy="edf", **kw)
    assert fifo.conserved and edf.conserved
    assert edf.total_completed == fifo.total_completed


# ------------------------------------------------------------- facade

def test_workloadspec_lm_phase(cfgs):
    wl_p = scope.WorkloadSpec.lm(cfgs, SEQ)
    wl_d = scope.WorkloadSpec.lm(cfgs, SEQ, phase="decode")
    assert wl_p.phase == "prefill" and wl_d.phase == "decode"
    assert "@prefill" in wl_p.models[0].graph.name
    assert "@decode" in wl_d.models[0].graph.name
    # decode= overrides phase=
    wl_o = scope.WorkloadSpec.lm(cfgs, SEQ, phase="prefill", decode=True)
    assert wl_o.phase == "decode"
    with pytest.raises(ValueError):
        scope.WorkloadSpec.lm(cfgs, SEQ, phase="train")


def test_batching_policy_queue_policy_validated():
    assert BatchingPolicy(queue_policy="edf").queue_policy == "edf"
    with pytest.raises(ValueError):
        BatchingPolicy(queue_policy="lifo")


def test_llm_fingerprint_sensitivity(cfgs):
    wl = scope.WorkloadSpec.lm(cfgs, SEQ, [2.0, 1.0])
    prob = scope.problem(wl, "mcm16", strategy="llm-phase",
                         output_tokens=OUT, m_samples=8)
    fp = scope.problem_fingerprint(prob)
    assert fp != scope.problem_fingerprint(
        prob.with_options(output_tokens=OUT * 2))
    assert fp != scope.problem_fingerprint(
        prob.with_options(phase_mode="colocated"))


def test_llm_solution_json_and_describe(llm_sol):
    js = llm_sol.to_json()
    assert js["feasible"] and js["mode"] in ("disaggregated", "colocated")
    assert js["token_rate"] > 0 and len(js["assignments"]) == 2
    for a in js["assignments"]:
        assert a["max_seqs"] > 0 and a["kv_capacity_bytes"] > 0
    assert any("mode=" in line for line in llm_sol.describe())


def test_simulate_tokens_wrapper(llm_sol, token_trace):
    trace, horizon = token_trace
    rep = simulate_tokens(llm_sol.llm, llm_sol.hw, trace,
                          horizon_s=horizon, seed=3)
    assert rep.conserved and rep.total_arrived == len(trace)
