"""Package partitioning: per-model chip quotas over an (optionally hetero) MCM.

The partitioned mode gives every model a dedicated region set drawn from one
chip flavor and runs the models' pipelines concurrently.  The search is
two-level:

1. per-(model, flavor) throughput curves (``curves.py``) -- all Scope
   sub-searches share one FastCostModel memo, so sweeping every quota size
   costs a small multiple of a single search;
2. enumeration over quota assignments: which flavor each model draws from,
   and how each flavor's chips split among its models.  This level is pure
   table lookups over the curves' monotone envelopes; it is exhaustive at
   ``step=1`` (cheap for the benchmark mixes: O(C^(N-1)) lookups) and walks
   a coarsened ``step``-chip grid for large packages / many models, where
   exact compositions would explode combinatorially.

On heterogeneous packages a third level opens up
(:func:`search_partitioned_mixed`): per-model quotas may *span* flavors, so
one model's pipeline starts on big chips and finishes on little ones
(``search_mixed``'s per-cluster flavor dimension).  Per-flavor chip splits
are enumerated independently per flavor (weak compositions, zeros allowed)
and looked up in 2D mixed envelopes (:class:`~.curves.MixedCurve`) that
combine the single-flavor curves with genuinely-mixed DSE points.

``brute_force_partitioned`` re-solves the same problem with fresh reference
searches per candidate -- exponentially slower, used by
``tests/test_multimodel.py`` to pin the table-based search on tiny cases.
"""
from __future__ import annotations

import itertools
import math

import numpy as np

from ..core.costmodel import INF, CostModel
from ..core.graph import (
    MM_PARTITIONED,
    ModelAssignment,
    MultiModelSchedule,
    mix_rate,
)
from ..core.hw import HardwareModel
from ..core.search import compositions, search
from .curves import build_curves, mixed_throughput_curve


def package_flavors(hw: HardwareModel) -> list[tuple[str | None, int]]:
    """The quota pools: ``[(chip_type_name, chips)]``; homogeneous packages
    are one anonymous pool."""
    if hw.region_types:
        return [(t.name, t.chips) for t in hw.region_types]
    return [(None, hw.chips)]


def _flavor_splits(cap: int, parts: int, step: int):
    """Splits of ``cap`` chips among ``parts`` models on a ``step`` grid.

    ``step == 1`` is every exact composition.  ``step > 1`` composes
    ``cap // step`` units of ``step`` chips (remainder to the first model) --
    the coarse grid for large packages; the curves' monotone envelope turns
    each quota into "at most this many chips", so coarse quotas stay valid,
    just less finely optimized.
    """
    if step <= 1 or cap < parts * step:
        yield from compositions(cap, parts)
        return
    units, rem = divmod(cap, step)
    for comp in compositions(units, parts):
        yield [c * step + (rem if i == 0 else 0) for i, c in enumerate(comp)]


def search_partitioned(
    specs,
    cost: CostModel,
    step: int = 1,
    paper_strict: bool = False,
    curves=None,
) -> MultiModelSchedule | None:
    """Best spatial partitioning of the package across the specs.

    The quota enumeration is evaluated as array programs, not a per-candidate
    Python loop: for each assignment of models to flavors, every flavor's
    chip splits are scored as one gather over the curves' envelope tables
    (per-split group minimum of weighted throughput).  The mix rate of a
    full candidate is the minimum across flavors, and the per-flavor maxima
    are independent, so the best split combination is recovered per flavor
    -- picking, for parity with the scalar scan this replaces, the *first*
    split on each flavor's grid that achieves the optimum.
    """
    hw = cost.hw
    flavors = package_flavors(hw)
    if curves is None:
        curves = build_curves(specs, cost, flavors, step, paper_strict)
    envelopes = {
        (name, ctype): curve.envelope(dict(flavors)[ctype])
        for (name, ctype), curve in curves.items()
    }
    # Weighted-throughput lookup tables over 0..cap chips, one per
    # (model, flavor): the whole quota grid reads from these via fancy
    # indexing instead of per-candidate attribute chasing.
    tp = {
        (name, ctype): np.array(
            [0.0 if pt is None else pt.throughput for pt in env],
            dtype=np.float64,
        )
        for (name, ctype), env in envelopes.items()
    }
    n = len(specs)
    best_lam, best_quota, n_candidates = -1.0, None, 0
    for type_assign in itertools.product(range(len(flavors)), repeat=n):
        groups: dict[int, list[int]] = {}
        for i, t in enumerate(type_assign):
            groups.setdefault(t, []).append(i)
        if any(len(g) > flavors[t][1] for t, g in groups.items()):
            continue
        # Per flavor: splits matrix (n_splits x group) and the per-split
        # group minimum of throughput/weight, in one vectorized pass.
        per_flavor = []
        count = 1
        for t, g in groups.items():
            splits = np.array(
                list(_flavor_splits(flavors[t][1], len(g), step)),
                dtype=np.int64,
            )
            ctype = flavors[t][0]
            vals = np.full(len(splits), INF)
            for col, i in enumerate(g):
                np.minimum(
                    vals,
                    tp[(specs[i].name, ctype)][splits[:, col]]
                    / specs[i].weight,
                    out=vals,
                )
            per_flavor.append((t, g, splits, vals))
            count *= len(splits)
        n_candidates += count
        # The candidate value is min over flavors; flavors split
        # independently, so the achievable optimum is the min of the
        # per-flavor maxima.
        lam = min(float(vals.max()) for _, _, _, vals in per_flavor)
        if lam > best_lam:
            best_lam = lam
            picks = [None] * n
            for t, g, splits, vals in per_flavor:
                # First split achieving >= lam: reproduces the scalar
                # product scan's pick (its first strict improvement to the
                # optimum is the lexicographically first combination whose
                # every flavor meets the bottleneck rate).
                row = int(np.argmax(vals >= lam))
                ctype = flavors[t][0]
                for col, i in enumerate(g):
                    c = int(splits[row, col])
                    picks[i] = (ctype, envelopes[(specs[i].name, ctype)][c])
            best_quota = picks
    if best_quota is None or best_lam <= 0.0:
        return None
    assignments = tuple(
        ModelAssignment(
            model=spec.name,
            weight=spec.weight,
            chips=pt.chips,
            schedule=pt.schedule,
            chip_type=ctype,
        )
        for spec, (ctype, pt) in zip(specs, best_quota)
    )
    lam = mix_rate(assignments)
    return MultiModelSchedule(
        package=hw.name,
        chips=hw.chips,
        mode=MM_PARTITIONED,
        assignments=assignments,
        mix_rate=lam,
        weighted_throughput=lam * sum(s.weight for s in specs),
        meta={
            "quota_candidates": n_candidates,
            "curve_points": sum(len(c.points) for c in curves.values()),
        },
    )


# Enumeration budget for the spanning quota search's split cross-product;
# beyond it the quota grid is coarsened (doubling steps) until it fits.
_MAX_SPLIT_CANDIDATES = 2_000_000


def _weak_splits(cap: int, parts: int, step: int):
    """Splits of up to ``cap`` chips among ``parts`` models, zeros allowed,
    on a ``step`` grid (exact-unit sums; the envelopes' "at most" semantics
    make exact-total splits lose no generality, and the ``cap % step``
    remainder tops up the first non-zero share)."""
    step = max(1, step)
    units, rem = divmod(cap, step)
    for comp in itertools.product(range(units + 1), repeat=parts - 1):
        head = sum(comp)
        if head > units:
            continue
        split = [c * step for c in comp] + [(units - head) * step]
        if rem:
            for i, c in enumerate(split):
                if c:
                    split[i] = c + rem
                    break
        yield split


def search_partitioned_mixed(
    specs,
    cost: CostModel,
    step: int = 1,
    paper_strict: bool = False,
    curves=None,
    mixed_curves=None,
    mixed_step: int | None = None,
    cut_window: int = 2,
    mixed_refine: bool = False,
) -> MultiModelSchedule | None:
    """Partitioned quotas where a model's quota may span chip flavors.

    Works on any heterogeneous package with two or more flavors (the
    big/little setting of SCAR / Odema et al. is the two-flavor case):
    per-flavor chip splits are enumerated independently per flavor and
    every split combination is scored as one array program over the models'
    F-dimensional mixed envelopes.  ``mixed_step`` walks the mixed curves'
    budget grid (default: quarter-capacity steps -- each point is a full
    mixed DSE, so the grid is deliberately coarser than the single-flavor
    curves'); ``mixed_refine`` adds the F-dimensional coarse-to-fine pass
    around each curve's argmax (:func:`~.curves.mixed_throughput_curve`).
    """
    hw = cost.hw
    flavors = package_flavors(hw)
    F = len(flavors)
    if F < 2:
        return None
    types = [t for t, _ in flavors]
    caps = [cap for _, cap in flavors]
    if curves is None:
        curves = build_curves(specs, cost, flavors, step, paper_strict)
    if mixed_step is None:
        mixed_step = max(1, min(caps) // 4)
    if mixed_curves is None:
        mixed_curves = {
            spec.name: mixed_throughput_curve(
                cost, spec.graph, flavors, step=mixed_step,
                paper_strict=paper_strict, cut_window=cut_window,
                refine=mixed_refine,
            )
            for spec in specs
        }
    env2 = {
        spec.name: mixed_curves[spec.name].envelope(
            tuple(caps),
            *[curves[(spec.name, t)].envelope(c) for t, c in flavors],
        )
        for spec in specs
    }
    n = len(specs)
    # The enumeration is the cross-product of the flavors' weak splits
    # (O((cap/step + 1)^(F(n-1))) candidates): coarsen the quota grid until
    # it is tractable -- the envelopes' "at most" semantics keep every
    # coarse quota valid, just less finely optimized (same policy as
    # _flavor_splits' step grid).
    quota_step = max(1, step)
    while math.prod(
        math.comb(cap // quota_step + n - 1, n - 1) for cap in caps
    ) > _MAX_SPLIT_CANDIDATES:
        quota_step *= 2
    # One splits matrix per flavor; the whole cross-product is scored as a
    # single F-dimensional tensor: per model, gather its envelope value at
    # every (split_0, ..., split_{F-1}) combination with np.ix_, take the
    # weighted min across models, and let the flat argmax (C order = the
    # nested-loop enumeration order this replaces, so first-occurrence
    # tie-breaks match) name the winning combination.
    splits = [
        np.array(list(_weak_splits(caps[f], n, quota_step)), dtype=np.int64)
        for f in range(F)
    ]
    n_candidates = math.prod(len(s) for s in splits)
    val = None
    for i, spec in enumerate(specs):
        env_tp = np.frompyfunc(
            lambda r: 0.0 if r is None else r[0], 1, 1
        )(env2[spec.name]).astype(np.float64) / spec.weight
        t = env_tp[np.ix_(*[splits[f][:, i] for f in range(F)])]
        val = t if val is None else np.minimum(val, t, out=val)
    flat = int(np.argmax(val))
    best_lam = float(val.flat[flat])
    if best_lam <= 0.0:
        return None
    combo = np.unravel_index(flat, val.shape)
    best_picks = [
        env2[spec.name][tuple(int(splits[f][combo[f], i]) for f in range(F))]
        for i, spec in enumerate(specs)
    ]
    assignments = []
    for spec, rec in zip(specs, best_picks):
        _tp, kind, fidx, pt = rec
        if kind == "single":
            assignments.append(ModelAssignment(
                model=spec.name, weight=spec.weight, chips=pt.chips,
                schedule=pt.schedule, chip_type=flavors[fidx][0],
            ))
        else:
            assignments.append(ModelAssignment(
                model=spec.name, weight=spec.weight,
                chips=int(sum(pt.quota)),
                schedule=pt.schedule,
                chip_quota=tuple(
                    (t, int(q)) for t, q in zip(types, pt.quota) if q
                ),
            ))
    assignments = tuple(assignments)
    lam = mix_rate(assignments)
    return MultiModelSchedule(
        package=hw.name,
        chips=hw.chips,
        mode=MM_PARTITIONED,
        assignments=assignments,
        mix_rate=lam,
        weighted_throughput=lam * sum(s.weight for s in specs),
        meta={
            "family": "partitioned_mixed",
            "quota_candidates": n_candidates,
            "quota_step": quota_step,
            "mixed_points": sum(len(c.points) for c in mixed_curves.values()),
            "mixed_step": mixed_step,
            "mixed_refine": mixed_refine,
        },
    )


def brute_force_partitioned(
    specs, hw: HardwareModel, m_samples: int = 16,
    cost_cls=CostModel,
) -> tuple[float, list[tuple[str | None, int]]]:
    """Exhaustive (flavor, chips) assignment with a fresh search per point.

    Idle chips are allowed (per-flavor sums may be < the pool), matching the
    quota search's monotone envelope.  Tiny cases only -- this is the test
    oracle, deliberately sharing no code with the table-based search.
    """
    flavors = package_flavors(hw)
    n = len(specs)
    best_lam, best_assign = 0.0, None
    for type_assign in itertools.product(range(len(flavors)), repeat=n):
        caps = [flavors[t][1] for t in type_assign]
        for chips_assign in itertools.product(
            *[range(1, c + 1) for c in caps]
        ):
            used: dict[int, int] = {}
            for t, c in zip(type_assign, chips_assign):
                used[t] = used.get(t, 0) + c
            if any(u > flavors[t][1] for t, u in used.items()):
                continue
            lam = INF
            for spec, t, c in zip(specs, type_assign, chips_assign):
                cost = cost_cls(hw, m_samples=m_samples)
                sched = search(spec.graph, cost, c, chip_type=flavors[t][0])
                tp = (
                    0.0 if sched is None or sched.latency == INF
                    else m_samples / sched.latency
                )
                lam = min(lam, tp / spec.weight)
            if lam > best_lam:
                best_lam = lam
                best_assign = [
                    (flavors[t][0], c)
                    for t, c in zip(type_assign, chips_assign)
                ]
    return best_lam, best_assign
