"""Per-model throughput curves over chip counts -- the quota search's table.

For each (model, chip flavor) the quota search needs ``throughput(c)`` for
every candidate quota ``c``.  Each point is a full Scope DSE
(``search(graph, cost, c, chip_type=t)``); all points share one
:class:`~repro.core.fastcost.FastCostModel`, whose cluster-cost memo is keyed
on ``(graph, layer range, partitions, region_chips, ..., chip_type)`` -- so
consecutive ``c`` values re-solve mostly-cached sub-problems and a whole
curve costs a small multiple of one search (engine stats in the fig11
benchmark demonstrate the reuse).

Scope throughput is *not* monotone in chips (NoP overheads / utilization
collapse, paper Fig. 9), so a quota of ``c`` chips is served by the best
schedule using **at most** ``c`` chips (the rest idle): the curve exposes
that monotone envelope via :meth:`ThroughputCurve.envelope`.

Two extensions for large / heterogeneous packages:

* **Coarse-to-fine sampling** (``refine=True``): sample the coarse ``step``
  grid, then re-sample at step 1 inside one coarse cell around the argmax.
  The envelope stays correct at every quota (coarse points lower-bound it);
  only the peak region gets the exact resolution, which is where the quota
  search's winning candidates live.  ~10x fewer searches on 512+ chip
  packages.
* **Mixed-flavor curves** (:class:`MixedCurve`): throughput over per-flavor
  chip budget *pairs*, each point a full mixed-flavor DSE
  (:func:`repro.core.search.search_mixed`) that may land different clusters
  of the pipeline on different flavors.  The quota search combines these
  with the single-flavor envelopes so one model of a co-schedule can span
  flavors.
"""
from __future__ import annotations

import itertools

from dataclasses import dataclass, field

from ..core.costmodel import INF, CostModel
from ..core.graph import LayerGraph, ScopeSchedule
from ..core.search import search, search_mixed
from ..obs import current_tracer


@dataclass
class CurvePoint:
    chips: int
    latency: float
    throughput: float
    schedule: ScopeSchedule | None


@dataclass
class ThroughputCurve:
    """throughput(c) for one (model, chip flavor), plus monotone envelope."""
    model: str
    chip_type: str | None
    points: dict[int, CurvePoint] = field(default_factory=dict)

    def envelope(self, max_chips: int) -> list[CurvePoint | None]:
        """``envelope()[c]`` = best point using at most ``c`` chips, for
        every c in 0..max_chips (index 0 is None) -- O(1) quota lookups."""
        out: list[CurvePoint | None] = [None] * (max_chips + 1)
        best = None
        for c in range(1, max_chips + 1):
            pt = self.points.get(c)
            if (
                pt is not None and pt.schedule is not None
                and (best is None or pt.throughput > best.throughput)
            ):
                best = pt
            out[c] = best
        return out


def candidate_counts(max_chips: int, step: int = 1) -> list[int]:
    """Curve sample points: all of 1..max_chips at ``step=1``; otherwise the
    same grid ``quota._flavor_splits`` enumerates -- multiples of ``step``
    plus the remainder-shifted multiples (the first model of a flavor group
    absorbs ``max_chips % step``) plus {1, max_chips} -- so every coarse
    quota resolves to a schedule actually sized for it."""
    step = max(1, step)
    if step == 1:
        return list(range(1, max_chips + 1))
    rem = max_chips % step
    pts = set(range(step, max_chips + 1, step)) | {1, max_chips}
    if rem:
        pts |= set(range(step + rem, max_chips + 1, step))
    return sorted(pts)


def throughput_curve(
    cost: CostModel,
    graph: LayerGraph,
    max_chips: int,
    chip_type: str | None = None,
    step: int = 1,
    paper_strict: bool = False,
    refine: bool = False,
) -> ThroughputCurve:
    curve = ThroughputCurve(graph.name, chip_type)

    def sample(c: int) -> None:
        sched = search(graph, cost, c, chip_type=chip_type,
                       paper_strict=paper_strict)
        if sched is None or sched.latency == INF:
            curve.points[c] = CurvePoint(c, INF, 0.0, None)
            return
        sched.meta["m_samples"] = cost.m
        curve.points[c] = CurvePoint(
            c, sched.latency, cost.m / sched.latency, sched
        )

    with current_tracer().span("curve", model=graph.name,
                               flavor=chip_type or "base",
                               max_chips=max_chips, step=step) as sp:
        for c in candidate_counts(max_chips, step):
            sample(c)
        if refine and step > 1:
            # Coarse-to-fine: fill the one-coarse-cell neighborhood of the
            # argmax at step 1, where the quota search's winners concentrate.
            best = max(
                (p for p in curve.points.values() if p.schedule is not None),
                key=lambda p: p.throughput,
                default=None,
            )
            if best is not None:
                lo = max(1, best.chips - step + 1)
                hi = min(max_chips, best.chips + step - 1)
                for c in range(lo, hi + 1):
                    if c not in curve.points:
                        sample(c)
        sp.set(points=len(curve.points))
    return curve


def build_curves(
    specs,
    cost: CostModel,
    flavors: list[tuple[str | None, int]],
    step: int = 1,
    paper_strict: bool = False,
    refine: bool = False,
) -> dict[tuple[str, str | None], ThroughputCurve]:
    """Curves for every (model, flavor) pair, all through one shared memo."""
    out = {}
    for spec in specs:
        for ctype, cap in flavors:
            out[(spec.name, ctype)] = throughput_curve(
                cost, spec.graph, cap, ctype, step, paper_strict, refine
            )
    return out


# ---------------------------------------------------------------------------
# Mixed-flavor curves: one model spanning two chip flavors
# ---------------------------------------------------------------------------

@dataclass
class MixedPoint:
    quota: tuple[int, int]         # chips per flavor, aligned with curve.flavors
    latency: float
    throughput: float
    schedule: ScopeSchedule | None


@dataclass
class MixedCurve:
    """throughput(c_a, c_b) for one model over two chip flavors."""
    model: str
    flavors: tuple[str | None, str | None]
    points: dict[tuple[int, int], MixedPoint] = field(default_factory=dict)

    def envelope(self, caps, env_a, env_b):
        """2D monotone envelope combining this curve with the flavors' 1D
        envelopes.

        ``table[a][b]`` is the best record reachable with at most ``a``
        chips of flavor 0 and ``b`` of flavor 1: ``(throughput, kind,
        flavor_idx, point)`` where ``kind`` is ``"single"`` (a 1D
        CurvePoint on one flavor) or ``"mixed"`` (a MixedPoint spanning
        both), or ``None`` when nothing fits.  O(caps[0] * caps[1]) DP.
        """
        A, B = caps

        def better(x, y):
            return y if x is None or (y is not None and y[0] > x[0]) else x

        table = [[None] * (B + 1) for _ in range(A + 1)]
        for a in range(A + 1):
            row = table[a]
            for b in range(B + 1):
                cand = None
                if a > 0 and env_a[a] is not None:
                    cand = better(cand, (env_a[a].throughput, "single", 0, env_a[a]))
                if b > 0 and env_b[b] is not None:
                    cand = better(cand, (env_b[b].throughput, "single", 1, env_b[b]))
                pt = self.points.get((a, b))
                if pt is not None and pt.schedule is not None:
                    cand = better(cand, (pt.throughput, "mixed", None, pt))
                if a > 0:
                    cand = better(cand, table[a - 1][b])
                if b > 0:
                    cand = better(cand, row[b - 1])
                row[b] = cand
        return table


# Refinement cell budget: a window sampled at step 1 may hold at most this
# many budget pairs; larger windows are walked with a coarser stride first
# (successive halving), so refinement cost stays bounded on big packages
# where a full step-1 cell would be (2*step-1)^2 mixed DSEs.
_MAX_REFINE_CELL = 81


def _refine_grid(center: int, span: int, cap: int, stride: int) -> list[int]:
    """Stride-spaced budgets covering ``center +- span``, clipped to [1, cap]
    (both window edges always included so the cell is fully bracketed)."""
    lo, hi = max(1, center - span), min(cap, center + span)
    pts = list(range(lo, hi + 1, stride))
    if pts[-1] != hi:
        pts.append(hi)
    return pts


def mixed_throughput_curve(
    cost: CostModel,
    graph: LayerGraph,
    flavors: list[tuple[str | None, int]],
    step: int = 1,
    paper_strict: bool = False,
    cut_window: int = 2,
    refine: bool = False,
) -> MixedCurve:
    """Sample mixed-flavor DSEs over the two flavors' budget grid.

    Only genuinely mixed budgets (both > 0) are sampled -- pure quotas are
    covered by the 1D curves, and :meth:`MixedCurve.envelope` merges both.
    ``step`` walks the same coarse grid as the 1D curves (a point's budget
    pair is a *cap*, so coarse points stay valid under the envelope).

    ``refine=True`` is the 2D analogue of the 1D coarse-to-fine curves:
    after the coarse grid, the one-coarse-cell neighborhood of the argmax
    budget pair is re-sampled down to step 1.  Small cells are filled
    exactly (mirroring the 1D pass); cells larger than
    ``_MAX_REFINE_CELL`` pairs are narrowed by successive halving --
    re-sample the window at a quarter of the current stride around the
    running argmax until stride 1 -- so the pass stays a bounded multiple
    of the coarse grid even at 512-chip flavors.
    """
    assert len(flavors) == 2, "mixed curves span exactly two flavors"
    (ta, cap_a), (tb, cap_b) = flavors
    curve = MixedCurve(graph.name, (ta, tb))

    def sample(qa: int, qb: int) -> None:
        sched = search_mixed(
            graph, cost, [(ta, qa), (tb, qb)],
            paper_strict=paper_strict, cut_window=cut_window,
            include_single_flavor=False,
        )
        if sched is None or sched.latency == INF:
            curve.points[(qa, qb)] = MixedPoint((qa, qb), INF, 0.0, None)
            return
        sched.meta["m_samples"] = cost.m
        curve.points[(qa, qb)] = MixedPoint(
            (qa, qb), sched.latency, cost.m / sched.latency, sched
        )

    with current_tracer().span("curve:mixed", model=graph.name,
                               flavors=f"{ta}/{tb}", step=step) as sp:
        for qa, qb in itertools.product(
            candidate_counts(cap_a, step), candidate_counts(cap_b, step)
        ):
            sample(qa, qb)

        s = step
        while refine and s > 1:
            best = max(
                (p for p in curve.points.values() if p.schedule is not None),
                key=lambda p: p.throughput,
                default=None,
            )
            if best is None:
                break
            span = s - 1
            stride = 1 if (2 * span + 1) ** 2 <= _MAX_REFINE_CELL else max(2, s // 4)
            for qa in _refine_grid(best.quota[0], span, cap_a, stride):
                for qb in _refine_grid(best.quota[1], span, cap_b, stride):
                    if (qa, qb) not in curve.points:
                        sample(qa, qb)
            if stride == 1:
                break
            s = stride
        sp.set(points=len(curve.points))
    return curve
