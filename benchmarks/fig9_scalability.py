"""Fig. 9: throughput scalability vs chiplet count at a fixed workload.

Paper claims reproduced: Scope scales best; segmented grows slower; the
fully-sequential method saturates (NoP-bound) and can even degrade; the
fully-pipelined method lacks valid solutions at low chip counts.
"""
from __future__ import annotations

from .common import cached, run_method

CHIPS = [16, 32, 64, 128, 256]
METHODS = ["sequential", "full_pipeline", "segmented", "scope"]
NET = "resnet50"


def run(refresh: bool = False, net: str = NET):
    rows = []
    for chips in CHIPS:
        def _one(chips=chips):
            return [run_method(net, chips, m) for m in METHODS]
        rows.extend(cached(f"fig9_{net}_{chips}", _one, refresh))
    return rows


def report(rows) -> list[str]:
    by = {}
    for r in rows:
        by.setdefault(r["method"], {})[r["chips"]] = r
    lines = ["method," + ",".join(f"x{c}" for c in CHIPS) + "  (normalized to 16 chips)"]
    for m in METHODS:
        base = by[m].get(CHIPS[0], {})
        base_tp = base.get("throughput") if base.get("valid") else None
        cells = []
        for c in CHIPS:
            r = by[m].get(c, {})
            if not r.get("valid"):
                cells.append("invalid")
            elif base_tp:
                cells.append(f"{r['throughput'] / base_tp:.2f}")
            else:
                cells.append(f"abs:{r['throughput']:.0f}")
        lines.append(f"{m}," + ",".join(cells))
    lines.append("method," + ",".join(f"x{c}" for c in CHIPS) + "  (absolute samples/s)")
    for m in METHODS:
        cells = []
        for c in CHIPS:
            r = by[m].get(c, {})
            cells.append(f"{r['throughput']:.0f}" if r.get("valid") else "invalid")
        lines.append(f"{m}," + ",".join(cells))
    best = all(
        by["scope"][c]["throughput"] >= by["segmented"][c]["throughput"]
        for c in CHIPS if by["scope"].get(c, {}).get("valid")
    )
    lines.append(f"# scope >= segmented at every scale: {best} "
                 "(paper Fig 9: Scope exhibits the best scalability)")
    return lines
