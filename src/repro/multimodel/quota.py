"""Package partitioning: per-model chip quotas over an (optionally hetero) MCM.

The partitioned mode gives every model a dedicated region set drawn from one
chip flavor and runs the models' pipelines concurrently.  The search is
two-level:

1. per-(model, flavor) throughput curves (``curves.py``) -- all Scope
   sub-searches share one FastCostModel memo, so sweeping every quota size
   costs a small multiple of a single search;
2. enumeration over quota assignments: which flavor each model draws from,
   and how each flavor's chips split among its models.  This level is pure
   table lookups over the curves' monotone envelopes; it is exhaustive at
   ``step=1`` (cheap for the benchmark mixes: O(C^(N-1)) lookups) and walks
   a coarsened ``step``-chip grid for large packages / many models, where
   exact compositions would explode combinatorially.

On heterogeneous packages a third level opens up
(:func:`search_partitioned_mixed`): per-model quotas may *span* flavors, so
one model's pipeline starts on big chips and finishes on little ones
(``search_mixed``'s per-cluster flavor dimension).  Per-flavor chip splits
are enumerated independently per flavor (weak compositions, zeros allowed)
and looked up in 2D mixed envelopes (:class:`~.curves.MixedCurve`) that
combine the single-flavor curves with genuinely-mixed DSE points.

``brute_force_partitioned`` re-solves the same problem with fresh reference
searches per candidate -- exponentially slower, used by
``tests/test_multimodel.py`` to pin the table-based search on tiny cases.
"""
from __future__ import annotations

import itertools
import math

from ..core.costmodel import INF, CostModel
from ..core.graph import (
    MM_PARTITIONED,
    ModelAssignment,
    MultiModelSchedule,
    mix_rate,
)
from ..core.hw import HardwareModel
from ..core.search import compositions, search
from .curves import build_curves, mixed_throughput_curve


def package_flavors(hw: HardwareModel) -> list[tuple[str | None, int]]:
    """The quota pools: ``[(chip_type_name, chips)]``; homogeneous packages
    are one anonymous pool."""
    if hw.region_types:
        return [(t.name, t.chips) for t in hw.region_types]
    return [(None, hw.chips)]


def _flavor_splits(cap: int, parts: int, step: int):
    """Splits of ``cap`` chips among ``parts`` models on a ``step`` grid.

    ``step == 1`` is every exact composition.  ``step > 1`` composes
    ``cap // step`` units of ``step`` chips (remainder to the first model) --
    the coarse grid for large packages; the curves' monotone envelope turns
    each quota into "at most this many chips", so coarse quotas stay valid,
    just less finely optimized.
    """
    if step <= 1 or cap < parts * step:
        yield from compositions(cap, parts)
        return
    units, rem = divmod(cap, step)
    for comp in compositions(units, parts):
        yield [c * step + (rem if i == 0 else 0) for i, c in enumerate(comp)]


def _enumerate_quotas(
    n_models: int, flavors: list[tuple[str | None, int]], step: int = 1
):
    """Yield ``[(flavor_idx, chips), ...]`` per model: every assignment of
    models to flavors x every split of each flavor's chips among its models
    (on the ``step`` quota grid).

    Splits are compositions of the full pool; quotas that would be better
    served by fewer chips are handled by the curves' monotone envelope
    (idle chips), so exact-sum compositions lose no generality.
    """
    for type_assign in itertools.product(range(len(flavors)), repeat=n_models):
        groups: dict[int, list[int]] = {}
        for i, t in enumerate(type_assign):
            groups.setdefault(t, []).append(i)
        if any(len(g) > flavors[t][1] for t, g in groups.items()):
            continue
        per_flavor = [
            (t, g, list(_flavor_splits(flavors[t][1], len(g), step)))
            for t, g in groups.items()
        ]
        for combo in itertools.product(*[opts for _, _, opts in per_flavor]):
            quota = [None] * n_models
            for (t, g, _), comp in zip(per_flavor, combo):
                for i, c in zip(g, comp):
                    quota[i] = (t, c)
            yield quota


def search_partitioned(
    specs,
    cost: CostModel,
    step: int = 1,
    paper_strict: bool = False,
    curves=None,
) -> MultiModelSchedule | None:
    """Best spatial partitioning of the package across the specs."""
    hw = cost.hw
    flavors = package_flavors(hw)
    if curves is None:
        curves = build_curves(specs, cost, flavors, step, paper_strict)
    envelopes = {
        (name, ctype): curve.envelope(dict(flavors)[ctype])
        for (name, ctype), curve in curves.items()
    }
    n = len(specs)
    best_lam, best_quota, n_candidates = -1.0, None, 0
    for quota in _enumerate_quotas(n, flavors, step):
        n_candidates += 1
        lam = INF
        picks = []
        for spec, (t, c) in zip(specs, quota):
            ctype = flavors[t][0]
            pt = envelopes[(spec.name, ctype)][c]
            tp = pt.throughput if pt else 0.0
            picks.append((ctype, pt))
            lam = min(lam, tp / spec.weight)
            if lam <= best_lam:
                break
        if lam > best_lam:
            best_lam, best_quota = lam, picks
    if best_quota is None or best_lam <= 0.0:
        return None
    assignments = tuple(
        ModelAssignment(
            model=spec.name,
            weight=spec.weight,
            chips=pt.chips,
            schedule=pt.schedule,
            chip_type=ctype,
        )
        for spec, (ctype, pt) in zip(specs, best_quota)
    )
    lam = mix_rate(assignments)
    return MultiModelSchedule(
        package=hw.name,
        chips=hw.chips,
        mode=MM_PARTITIONED,
        assignments=assignments,
        mix_rate=lam,
        weighted_throughput=lam * sum(s.weight for s in specs),
        meta={
            "quota_candidates": n_candidates,
            "curve_points": sum(len(c.points) for c in curves.values()),
        },
    )


# Enumeration budget for the spanning quota search's split cross-product;
# beyond it the quota grid is coarsened (doubling steps) until it fits.
_MAX_SPLIT_CANDIDATES = 2_000_000


def _weak_splits(cap: int, parts: int, step: int):
    """Splits of up to ``cap`` chips among ``parts`` models, zeros allowed,
    on a ``step`` grid (exact-unit sums; the envelopes' "at most" semantics
    make exact-total splits lose no generality, and the ``cap % step``
    remainder tops up the first non-zero share)."""
    step = max(1, step)
    units, rem = divmod(cap, step)
    for comp in itertools.product(range(units + 1), repeat=parts - 1):
        head = sum(comp)
        if head > units:
            continue
        split = [c * step for c in comp] + [(units - head) * step]
        if rem:
            for i, c in enumerate(split):
                if c:
                    split[i] = c + rem
                    break
        yield split


def search_partitioned_mixed(
    specs,
    cost: CostModel,
    step: int = 1,
    paper_strict: bool = False,
    curves=None,
    mixed_curves=None,
    mixed_step: int | None = None,
    cut_window: int = 2,
    mixed_refine: bool = False,
) -> MultiModelSchedule | None:
    """Partitioned quotas where a model's quota may span two chip flavors.

    Requires a heterogeneous package with exactly two flavors (the
    big/little setting of SCAR / Odema et al.; more flavors fall back to
    ``search_partitioned``'s single-flavor quotas -- ``co_schedule`` makes
    that fallback explicit with a warning and result meta).  ``mixed_step``
    walks the mixed curves' budget grid (default: quarter-capacity steps --
    each point is a full mixed DSE, so the grid is deliberately coarser
    than the single-flavor curves'); ``mixed_refine`` adds the 2D
    coarse-to-fine pass around each curve's argmax
    (:func:`~.curves.mixed_throughput_curve`).
    """
    hw = cost.hw
    flavors = package_flavors(hw)
    if len(flavors) != 2:
        return None
    (ta, cap_a), (tb, cap_b) = flavors
    if curves is None:
        curves = build_curves(specs, cost, flavors, step, paper_strict)
    if mixed_step is None:
        mixed_step = max(1, min(cap_a, cap_b) // 4)
    if mixed_curves is None:
        mixed_curves = {
            spec.name: mixed_throughput_curve(
                cost, spec.graph, flavors, step=mixed_step,
                paper_strict=paper_strict, cut_window=cut_window,
                refine=mixed_refine,
            )
            for spec in specs
        }
    env2 = {
        spec.name: mixed_curves[spec.name].envelope(
            (cap_a, cap_b),
            curves[(spec.name, ta)].envelope(cap_a),
            curves[(spec.name, tb)].envelope(cap_b),
        )
        for spec in specs
    }
    n = len(specs)
    # The enumeration is the cross-product of the two flavors' weak splits
    # (O((cap/step + 1)^(2(n-1))) candidates): coarsen the quota grid until
    # it is tractable -- the envelopes' "at most" semantics keep every
    # coarse quota valid, just less finely optimized (same policy as
    # _flavor_splits' step grid).
    quota_step = max(1, step)
    while (
        math.comb(cap_a // quota_step + n - 1, n - 1)
        * math.comb(cap_b // quota_step + n - 1, n - 1)
        > _MAX_SPLIT_CANDIDATES
    ):
        quota_step *= 2
    best_lam, best_picks, n_candidates = -1.0, None, 0
    for split_a in _weak_splits(cap_a, n, quota_step):
        for split_b in _weak_splits(cap_b, n, quota_step):
            n_candidates += 1
            lam = INF
            picks = []
            for spec, a, b in zip(specs, split_a, split_b):
                rec = env2[spec.name][a][b]
                tp = rec[0] if rec is not None else 0.0
                picks.append(rec)
                lam = min(lam, tp / spec.weight)
                if lam <= best_lam:
                    break
            if lam > best_lam:
                best_lam, best_picks = lam, picks
    if best_picks is None or best_lam <= 0.0:
        return None
    assignments = []
    for spec, rec in zip(specs, best_picks):
        _tp, kind, fidx, pt = rec
        if kind == "single":
            assignments.append(ModelAssignment(
                model=spec.name, weight=spec.weight, chips=pt.chips,
                schedule=pt.schedule, chip_type=flavors[fidx][0],
            ))
        else:
            qa, qb = pt.quota
            assignments.append(ModelAssignment(
                model=spec.name, weight=spec.weight, chips=qa + qb,
                schedule=pt.schedule,
                chip_quota=((ta, qa), (tb, qb)),
            ))
    assignments = tuple(assignments)
    lam = mix_rate(assignments)
    return MultiModelSchedule(
        package=hw.name,
        chips=hw.chips,
        mode=MM_PARTITIONED,
        assignments=assignments,
        mix_rate=lam,
        weighted_throughput=lam * sum(s.weight for s in specs),
        meta={
            "family": "partitioned_mixed",
            "quota_candidates": n_candidates,
            "quota_step": quota_step,
            "mixed_points": sum(len(c.points) for c in mixed_curves.values()),
            "mixed_step": mixed_step,
            "mixed_refine": mixed_refine,
        },
    )


def brute_force_partitioned(
    specs, hw: HardwareModel, m_samples: int = 16,
    cost_cls=CostModel,
) -> tuple[float, list[tuple[str | None, int]]]:
    """Exhaustive (flavor, chips) assignment with a fresh search per point.

    Idle chips are allowed (per-flavor sums may be < the pool), matching the
    quota search's monotone envelope.  Tiny cases only -- this is the test
    oracle, deliberately sharing no code with the table-based search.
    """
    flavors = package_flavors(hw)
    n = len(specs)
    best_lam, best_assign = 0.0, None
    for type_assign in itertools.product(range(len(flavors)), repeat=n):
        caps = [flavors[t][1] for t in type_assign]
        for chips_assign in itertools.product(
            *[range(1, c + 1) for c in caps]
        ):
            used: dict[int, int] = {}
            for t, c in zip(type_assign, chips_assign):
                used[t] = used.get(t, 0) + c
            if any(u > flavors[t][1] for t, u in used.items()):
                continue
            lam = INF
            for spec, t, c in zip(specs, type_assign, chips_assign):
                cost = cost_cls(hw, m_samples=m_samples)
                sched = search(spec.graph, cost, c, chip_type=flavors[t][0])
                tp = (
                    0.0 if sched is None or sched.latency == INF
                    else m_samples / sched.latency
                )
                lam = min(lam, tp / spec.weight)
            if lam > best_lam:
                best_lam = lam
                best_assign = [
                    (flavors[t][0], c)
                    for t, c in zip(type_assign, chips_assign)
                ]
    return best_lam, best_assign
