"""Pure-jnp oracle for flash attention (identical masking semantics)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def attention_ref(
    q: jax.Array,      # [B, H, Sq, hd]
    k: jax.Array,      # [B, KV, Skv, hd]
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    g = H // KV
    kf = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32), kf) / math.sqrt(hd)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", p, vf)
    return out.astype(q.dtype)
