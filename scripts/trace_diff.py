#!/usr/bin/env python
"""Diff two Chrome trace-event JSON files from ``repro.obs``.

Aggregates each trace into (process, span-name) self-time/total/count rows
-- the same nesting-aware accounting as ``Tracer.summary()`` -- plus
(process, counter-name) last/max/sample rows, then prints the B-vs-A
deltas.  A self-diff reports zero deltas by construction, which CI
asserts; between two runs the table answers "where did the time move?".

Usage::

    python scripts/trace_diff.py before.json after.json
    python scripts/trace_diff.py trace.json trace.json --fail-on-delta
"""
from __future__ import annotations

import argparse
import json
import sys


def _load(path: str) -> list[dict]:
    with open(path) as fh:
        if path.endswith(".jsonl"):
            return [json.loads(line) for line in fh if line.strip()]
        payload = json.load(fh)
    return payload.get("traceEvents", [])


def aggregate(events: list[dict]) -> tuple[dict, dict]:
    """-> (span rows, counter rows).

    Span rows: ``(process, name) -> [count, total_us, self_us]`` with child
    time subtracted per (pid, tid) lane.  Counter rows:
    ``(process, name) -> [samples, last, max]``.
    """
    pid_name: dict = {}
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "process_name":
            pid_name[ev.get("pid")] = ev.get("args", {}).get("name", "")

    lanes: dict[tuple, list] = {}
    counters: dict[tuple, list] = {}
    for ev in events:
        ph = ev.get("ph")
        if ph == "X":
            lanes.setdefault((ev.get("pid"), ev.get("tid")), []).append(ev)
        elif ph == "C":
            key = (pid_name.get(ev.get("pid"), str(ev.get("pid"))),
                   ev.get("name"))
            row = counters.setdefault(key, [0, 0.0, float("-inf")])
            v = float(ev.get("args", {}).get("value", 0.0))
            row[0] += 1
            row[1] = v
            row[2] = max(row[2], v)

    spans: dict[tuple, list] = {}
    for (pid, _tid), evs in lanes.items():
        evs.sort(key=lambda e: (e.get("ts", 0), -e.get("dur", 0)))
        stack: list = []
        for ev in evs:
            t0 = ev.get("ts", 0)
            dur = ev.get("dur", 0)
            t1 = t0 + dur
            while stack and t0 >= stack[-1][1] - 1e-9:
                stack.pop()
            key = (pid_name.get(pid, str(pid)), ev.get("name"))
            row = spans.setdefault(key, [0, 0.0, 0.0])
            row[0] += 1
            row[1] += dur
            row[2] += dur
            if stack:
                spans[stack[-1][2]][2] -= dur
            stack.append((t0, t1, key))
    return spans, counters


def _diff(a: dict, b: dict, cols) -> list[tuple]:
    out = []
    for key in sorted(set(a) | set(b), key=str):
        ra, rb = a.get(key), b.get(key)
        za = ra if ra is not None else [0] * len(cols)
        zb = rb if rb is not None else [0] * len(cols)
        deltas = [zb[i] - za[i] for i in range(len(cols))]
        if any(d != 0 for d in deltas) or ra is None or rb is None:
            out.append((key, za, zb, deltas))
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace_a", help="baseline Chrome trace JSON")
    ap.add_argument("trace_b", help="comparison Chrome trace JSON")
    ap.add_argument("--tol-us", type=float, default=0.0,
                    help="ignore span-time deltas at or below this many µs")
    ap.add_argument("--fail-on-delta", action="store_true",
                    help="exit 1 if any delta survives the tolerance")
    args = ap.parse_args()

    spans_a, ctr_a = aggregate(_load(args.trace_a))
    spans_b, ctr_b = aggregate(_load(args.trace_b))

    span_rows = [
        row for row in _diff(spans_a, spans_b, ("count", "total", "self"))
        if row[0] not in spans_a or row[0] not in spans_b
        or row[3][0] != 0 or abs(row[3][1]) > args.tol_us
        or abs(row[3][2]) > args.tol_us
    ]
    ctr_rows = _diff(ctr_a, ctr_b, ("samples", "last", "max"))

    n = len(span_rows) + len(ctr_rows)
    if span_rows:
        print(f"{'span':<42} {'d_count':>8} {'d_total_us':>12} "
              f"{'d_self_us':>12}")
        for (proc, name), _a, _b, d in span_rows:
            print(f"{proc + '/' + str(name):<42.42} {d[0]:>8} "
                  f"{d[1]:>12.3f} {d[2]:>12.3f}")
    if ctr_rows:
        print(f"{'counter':<42} {'d_samples':>9} {'d_last':>12} "
              f"{'d_max':>12}")
        for (proc, name), _a, _b, d in ctr_rows:
            print(f"{proc + '/' + str(name):<42.42} {d[0]:>9} "
                  f"{d[1]:>12.4g} {d[2]:>12.4g}")
    print(f"trace_diff: {args.trace_a} vs {args.trace_b}: "
          f"{n} delta row(s)")
    if args.fail_on_delta and n:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
