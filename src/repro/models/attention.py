"""GQA attention: prefill (full/sliding-window causal) and KV-cache decode.

The jnp path here is the reference implementation used for dry-run lowering
and CPU tests; on real TPUs the Pallas flash kernel
(``repro.kernels.flash_attention``) substitutes for the prefill einsum path
(``impl="pallas"``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, dense, rope_freqs, softcap

NEG_INF = -2.0e38


def init_attn(key, cfg: ModelConfig, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(ks[0], (d, h * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, kv * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, kv * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (h * hd, d)) * (h * hd) ** -0.5).astype(dtype),
    }


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def attention_scores(q, k, scale, cap):
    """q [B,S,H,hd], k [B,T,KV,hd] -> scores [B,H,S,T] with GQA broadcast."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(B, S, KV, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = softcap(scores * scale, cap)
    return scores.reshape(B, KV * g, S, k.shape[1])


def attention_prefill(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    window: int = 0,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Full-sequence causal attention.  Returns (out, (k, v)) for caching."""
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(dense(x, params["wq"]), H, hd)
    k = _split_heads(dense(x, params["wk"]), KV, hd)
    v = _split_heads(dense(x, params["wv"]), KV, hd)
    cos, sin = rope_freqs(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    scores = attention_scores(q, k, hd ** -0.5, cfg.attn_softcap)  # [B,H,S,S]
    i = positions[:, :, None]          # query positions [B,S,1]
    j = positions[:, None, :]          # key positions   [B,1,S]
    mask = j <= i
    if window > 0:
        mask &= j > i - window
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)

    g = H // KV
    pg = probs.reshape(B, KV, g, S, S)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.reshape(B, KV, g, S, S), v.astype(jnp.float32))
    out = out.reshape(B, S, H * hd).astype(x.dtype)
    return dense(out, params["wo"]), (k, v)


def attention_decode(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    cache_k: jax.Array,        # [B, T, KV, hd]
    cache_v: jax.Array,
    position: jax.Array,       # [B] current write index
    window: int = 0,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """One-token decode against a KV cache, in-place cache update."""
    B, S1, _ = x.shape
    assert S1 == 1
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    T = cache_k.shape[1]
    q = _split_heads(dense(x, params["wq"]), H, hd)
    k = _split_heads(dense(x, params["wk"]), KV, hd)
    v = _split_heads(dense(x, params["wv"]), KV, hd)
    cos, sin = rope_freqs(position[:, None], hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, position].set(k[:, 0])
    cache_v = cache_v.at[bidx, position].set(v[:, 0])

    scores = attention_scores(q, cache_k, hd ** -0.5, cfg.attn_softcap)  # [B,H,1,T]
    j = jnp.arange(T)[None, :]
    valid = j <= position[:, None]
    if window > 0:
        valid &= j > position[:, None] - window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    g = H // KV
    out = jnp.einsum(
        "bkgst,btkh->bskgh", probs.reshape(B, KV, g, 1, T), cache_v.astype(jnp.float32)
    )
    out = out.reshape(B, 1, H * hd).astype(x.dtype)
    return dense(out, params["wo"]), (cache_k, cache_v)
