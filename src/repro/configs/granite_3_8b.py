"""granite-3-8b [dense]: 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155 [hf:ibm-granite/granite-3.0-8b-base]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab=49155,
    ffn_gated=True,
    rope_theta=10_000.0,
)
