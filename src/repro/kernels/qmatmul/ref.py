"""jnp oracle for the int8 matmul + symmetric quantization helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_rows(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-row int8 quantization.  x [M, K] float."""
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def quantize_cols(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    q, s = quantize_rows(w.T)
    return q.T, s


def qmatmul_ref(x_q, w_q, x_scale, w_scale, out_dtype=jnp.float32):
    acc = jnp.matmul(
        x_q.astype(jnp.int32), w_q.astype(jnp.int32), preferred_element_type=jnp.int32
    )
    return (acc.astype(jnp.float32) * x_scale[:, None] * w_scale[None, :]).astype(out_dtype)
