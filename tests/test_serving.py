"""Serving executor tests: determinism, conservation, resource enforcement,
autoscale, the solve_many/SolutionCache facade, placement checks, and the
Deployment edge cases the executor exercises."""
from __future__ import annotations

import json
from dataclasses import replace

import pytest

from repro import scope
from repro.core.graph import MM_TIME_MUX
from repro.core.hw import get_hw, mcm_hetero
from repro.core.regions import flavor_zones, zigzag_placement
from repro.serving import (
    MMPP,
    AutoscalePolicy,
    BatchingPolicy,
    Diurnal,
    Poisson,
    ServingExecutor,
    allocate_submeshes,
    phased_trace,
    request_trace,
    service_from_assignment,
)


@pytest.fixture(scope="module")
def co16():
    """A 2-model co-schedule on mcm16 (partitioned mode) + its solution."""
    prob = scope.problem("alexnet:1,resnet18:1", "mcm16", m_samples=16)
    sol = scope.solve(prob)
    assert sol.feasible and sol.multi.mode == "partitioned"
    return sol


@pytest.fixture(scope="module")
def hetero_co():
    """Big/little co-schedule on a heterogeneous package."""
    prob = scope.problem("resnet50:4,resnet18:1", "mcm16_hetero",
                         m_samples=16)
    sol = scope.solve(prob)
    assert sol.feasible
    return sol


@pytest.fixture(scope="module")
def spanning_co():
    """A co-schedule whose winning quota spans both flavors (chip_quota):
    a model whose weights overflow either flavor alone (the
    test_multimodel spanning construction)."""
    from repro.core.fastcost import FastCostModel
    from repro.core.graph import LayerNode, chain
    from repro.core.hw import mcm_table_iii
    from repro.multimodel import ModelSpec
    from repro.multimodel.coschedule import co_schedule

    cap = mcm_table_iii(4).weight_capacity_per_chip
    layers = [
        LayerNode(
            name=f"l{i}", kind="conv", flops=1e9,
            weight_bytes=1.5 * cap, in_bytes=32e3, out_bytes=24e3,
            wsp_parallel=28.0, isp_parallel=128.0,
        )
        for i in range(2)
    ]
    g = chain("fat", layers)
    hw = mcm_hetero(4, big_fraction=0.5,
                    little_flops_scale=0.9, little_nop_scale=0.9)
    mm = co_schedule([ModelSpec(g, 1.0)], hw,
                     cost=FastCostModel(hw, m_samples=16))
    assert mm is not None
    assert any(a.chip_quota for a in mm.assignments)
    return mm, hw


# ---------------------------------------------------------------------------
# traffic
# ---------------------------------------------------------------------------

class TestTraffic:
    def test_trace_deterministic_and_sorted(self):
        traffic = {"a": Poisson(500.0), "b": MMPP(200.0, 2000.0),
                   "c": Diurnal(800.0, 100.0, period_s=0.5)}
        t1 = request_trace(traffic, 1.0, seed=7)
        t2 = request_trace(traffic, 1.0, seed=7)
        assert t1 == t2
        assert t1 != request_trace(traffic, 1.0, seed=8)
        assert all(x.t_arrive <= y.t_arrive for x, y in zip(t1, t1[1:]))
        assert {r.model for r in t1} == {"a", "b", "c"}

    def test_streams_independent_of_other_models(self):
        """Removing one model must not perturb another's arrivals."""
        both = request_trace({"a": Poisson(300.0), "b": Poisson(300.0)},
                             1.0, seed=3)
        alone = request_trace({"a": Poisson(300.0)}, 1.0, seed=3)
        assert [r.t_arrive for r in both if r.model == "a"] == \
            [r.t_arrive for r in alone]

    def test_phased_trace_flips_mix(self):
        trace = phased_trace(
            [({"a": 400.0, "b": 100.0}, 1.0), ({"a": 100.0, "b": 400.0}, 1.0)],
            seed=0,
        )
        p1 = [r for r in trace if r.t_arrive < 1.0]
        p2 = [r for r in trace if r.t_arrive >= 1.0]
        assert sum(r.model == "a" for r in p1) > 2 * sum(r.model == "b" for r in p1)
        assert sum(r.model == "b" for r in p2) > 2 * sum(r.model == "a" for r in p2)


# ---------------------------------------------------------------------------
# executor core
# ---------------------------------------------------------------------------

class TestExecutor:
    def test_seed_deterministic_report(self, co16):
        a = co16.serve(n_requests=400, seed=5)
        b = co16.serve(n_requests=400, seed=5)
        assert json.dumps(a.to_json(), sort_keys=True) == \
            json.dumps(b.to_json(), sort_keys=True)
        c = co16.serve(n_requests=400, seed=6)
        assert json.dumps(b.to_json(), sort_keys=True) != \
            json.dumps(c.to_json(), sort_keys=True)

    def test_request_conservation(self, co16):
        rep = co16.serve(n_requests=500, seed=1)
        assert rep.conserved
        assert rep.total_dropped == 0
        assert rep.total_arrived == rep.total_completed
        for m in rep.per_model.values():
            assert m.arrived_samples == m.completed_samples

    def test_admission_cap_drops_are_accounted(self, co16):
        rep = co16.serve(n_requests=500, seed=1, rate_scale=2.0, max_queue=8)
        assert rep.total_dropped > 0
        assert rep.conserved      # arrived == completed + dropped

    def test_saturated_server_matches_dse_throughput(self, co16):
        """The service law's whole point: a saturated simulated server
        reproduces the solved schedule's samples/s."""
        mm = co16.multi
        for a in mm.assignments:
            svc = service_from_assignment(a)
            m = a.schedule.meta["m_samples"]
            batch_rate = m / svc.service_s(m)
            assert batch_rate == pytest.approx(
                a.throughput / a.time_share, rel=1e-9)

    def test_overload_goodput_caps_at_capacity(self, co16):
        rep = co16.serve(n_requests=800, seed=2, rate_scale=1.6)
        assert rep.conserved
        solved = rep.meta["solved_weighted_throughput"]
        assert rep.goodput <= solved * 1.02
        assert rep.goodput >= solved * 0.75   # drain tail only, not collapse

    def test_queue_and_latency_metrics_populated(self, co16):
        rep = co16.serve(n_requests=400, seed=3)
        for m in rep.per_model.values():
            assert m.latency_p50_s <= m.latency_p95_s <= m.latency_p99_s \
                <= m.latency_max_s
            assert m.batches >= 1
            assert 0 < m.utilization <= 1.0
        assert 0 < rep.utilization <= 1.0

    def test_single_model_solution_serves(self):
        sol = scope.solve(scope.problem("resnet18", "mcm16", m_samples=16))
        rep = sol.serve(n_requests=200, seed=0)
        assert rep.conserved and rep.total_completed > 0
        mm = sol.as_multimodel()
        assert mm.meta["wrapped_single_model"]
        assert mm.assignments[0].chips <= sol.hw.chips

    def test_slo_gates_goodput(self):
        # third mix field = SLO in ms; an absurdly tight SLO zeroes goodput
        sol = scope.solve(
            scope.problem("alexnet:1:0.0001,resnet18:1", "mcm16",
                          m_samples=16))
        rep = sol.serve(n_requests=300, seed=0)
        alex = rep.per_model["alexnet"]
        assert alex.slo_s == pytest.approx(1e-7)
        assert alex.slo_attainment == 0.0
        assert alex.goodput == 0.0
        assert rep.goodput < rep.throughput


# ---------------------------------------------------------------------------
# resource enforcement
# ---------------------------------------------------------------------------

class TestEnforcement:
    def test_partitioned_submeshes_disjoint_and_sized(self, co16):
        mm, hw = co16.multi, co16.hw
        placement = allocate_submeshes(mm, hw)
        seen = set()
        for a in mm.assignments:
            coords = [c for zone in placement[a.model].values() for c in zone]
            assert len(coords) == a.chips
            assert not (set(coords) & seen)
            seen |= set(coords)
        assert len(seen) <= hw.chips

    def test_spanning_quota_gets_seam_adjacent_slices(self, spanning_co):
        mm, hw = spanning_co
        spanning = [a for a in mm.assignments if a.chip_quota]
        assert spanning, "fixture must contain a flavor-spanning quota"
        placement = allocate_submeshes(mm, hw)
        zones = flavor_zones([(t.name, t.chips) for t in hw.region_types],
                             hw.mesh_shape)
        for a in spanning:
            got = placement[a.model]
            for (f, c) in a.chip_quota:
                if c:
                    assert len(got[f]) == c
            # earlier flavor's slice must touch its zone end (the seam)
            first_flavor = a.chip_quota[0][0]
            if a.chip_quota[0][1]:
                assert got[first_flavor][-1] == zones[first_flavor][-1]
            # later flavor's slice starts at its zone front (the seam)
            second_flavor = a.chip_quota[1][0]
            if a.chip_quota[1][1]:
                assert got[second_flavor][0] == zones[second_flavor][0]

    def test_overcommitted_quota_rejected(self, co16):
        mm, hw = co16.multi, co16.hw
        bloated = replace(
            mm,
            assignments=tuple(
                replace(a, chips=hw.chips) for a in mm.assignments
            ),
        )
        with pytest.raises(ValueError, match="overcommit"):
            allocate_submeshes(bloated, hw)

    def test_time_mux_batches_run_inside_slices(self, co16):
        """Slice-enforcement invariant: every batch's busy time lies inside
        its model's periodic windows, and windows of different models never
        overlap."""
        tm = scope.solve(
            co16.problem.with_options(strategy="time-mux",
                                      switch_cost=True))
        assert tm.multi.mode == MM_TIME_MUX
        mm = tm.as_multimodel()
        ex = ServingExecutor(mm, tm.hw, seed=0)
        trace = request_trace(
            {a.model: 0.5 * mm.mix_rate * a.weight for a in mm.assignments},
            0.5, seed=0)
        rep = ex.run(trace)
        assert rep.conserved
        for model, log in ex.batch_log.items():
            srv = ex.servers[model]
            assert srv.window is not None
            for (start, done, work, _samples, _window) in log:
                in_window = srv.window_time(start, done)
                assert in_window == pytest.approx(work, rel=1e-6, abs=1e-9)
        # pairwise window disjointness within the period (useful spans
        # start after each slice's reload time)
        windows = [s.window for s in ex.servers.values()]
        period = windows[0][2]
        spans = sorted((off % period, off % period + span)
                       for off, span, _ in windows)
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert a1 <= b0 + 1e-9
        # switch cost charged: useful spans + reloads fit the period
        reloads = tm.multi.meta["reload_s"]
        assert sum(r for r in reloads) > 0
        assert sum(s[1] - s[0] for s in spans) + sum(reloads) \
            <= period * (1 + 1e-9)

    def test_merged_mode_interleaves_at_weighted_rates(self):
        from repro.multimodel.interleave import search_merged
        from repro.multimodel.spec import parse_mix

        specs = parse_mix("alexnet:2,resnet18:1")
        from repro.core.fastcost import FastCostModel

        hw = get_hw("mcm16")
        mm = search_merged(specs, FastCostModel(hw, m_samples=16))
        assert mm is not None and mm.mode == "merged"
        ex = ServingExecutor(mm, hw, seed=0)
        trace = request_trace(
            {a.model: 0.5 * mm.mix_rate * a.weight for a in mm.assignments},
            0.2, seed=0)
        rep = ex.run(trace)
        assert rep.conserved
        # the heavier-weighted model is served at a proportionally faster
        # per-sample rate (its samples_per_beat scales the service law)
        svc = {a.model: service_from_assignment(a) for a in mm.assignments}
        spb = {a.model: a.samples_per_beat for a in mm.assignments}
        assert spb["alexnet"] > spb["resnet18"]
        assert svc["alexnet"].service_s(8) < svc["resnet18"].service_s(8)
        # saturation consistency: a batch of m * spb samples reproduces
        # each model's DSE throughput exactly (max_batch is in beats, so
        # the default batcher actually reaches this operating point)
        for a in mm.assignments:
            m = a.schedule.meta["m_samples"]
            b = m * a.samples_per_beat
            rate = b / svc[a.model].service_s(b)
            assert rate == pytest.approx(a.throughput, rel=1e-9)
        # at 0.95x solved load the merged deployment must keep up
        traffic, horizon = {}, 1.0
        lam = mm.mix_rate * 0.95
        traffic = {a.model: lam * a.weight for a in mm.assignments}
        rep2 = ServingExecutor(mm, hw, seed=1).run(
            request_trace(traffic, 1.0, seed=1), horizon_s=1.0)
        assert rep2.conserved
        assert rep2.goodput >= 0.85 * mm.weighted_throughput
        assert rep2.utilization <= 1.0 + 1e-9


# ---------------------------------------------------------------------------
# flavor-aware placement
# ---------------------------------------------------------------------------

class TestFlavorPlacement:
    COUNTS = [("big", 8), ("little", 8)]

    def test_zones_partition_the_mesh(self):
        zones = flavor_zones(self.COUNTS, (4, 4))
        assert len(zones["big"]) == 8 and len(zones["little"]) == 8
        assert not (set(zones["big"]) & set(zones["little"]))

    def test_flavorless_placement_unchanged(self):
        legacy = zigzag_placement([5, 7, 4], (4, 4))
        assert sum(len(r) for r in legacy) == 16

    def test_runs_pinned_to_seam(self):
        zones = flavor_zones(self.COUNTS, (4, 4))
        regions = zigzag_placement(
            [2, 3, 4], (4, 4),
            region_flavors=["big", "big", "little"],
            flavor_counts=self.COUNTS,
        )
        # big run right-aligned against the seam, little run starts at it
        assert regions[1][-1] == zones["big"][-1]
        assert regions[2][0] == zones["little"][0]
        for r, f in zip(regions, ["big", "big", "little"]):
            assert set(r) <= set(zones[f])

    def test_non_contiguous_flavor_runs_rejected(self):
        with pytest.raises(ValueError, match="non-contiguous"):
            zigzag_placement(
                [2, 2, 2], (4, 4),
                region_flavors=["big", "little", "big"],
                flavor_counts=self.COUNTS,
            )

    def test_zone_overflow_rejected(self):
        with pytest.raises(ValueError, match="need"):
            zigzag_placement(
                [9], (4, 4),
                region_flavors=["big"],
                flavor_counts=self.COUNTS,
            )

    def test_check_stage_placement_on_plans(self, hetero_co):
        from repro.runtime.planner import check_stage_placement, \
            schedule_stages

        hw = hetero_co.hw
        for a in hetero_co.multi.assignments:
            for seg in a.schedule.segments:
                stages = tuple(
                    (cl.layer_lo, cl.layer_hi, cl.chip_type, cl.region_chips)
                    for cl in seg.clusters
                )
                coords = check_stage_placement(stages, hw)
                assert [len(c) for c in coords] == \
                    [cl.region_chips for cl in seg.clusters]
        bad = ((0, 1, "big", 2), (1, 2, "little", 2), (2, 3, "big", 2))
        with pytest.raises(ValueError, match="non-contiguous"):
            check_stage_placement(bad, hw)


# ---------------------------------------------------------------------------
# autoscale + solve_many / SolutionCache
# ---------------------------------------------------------------------------

class TestAutoscale:
    POLICY = AutoscalePolicy(window_s=0.05, check_every_s=0.01,
                             drift_threshold=0.5, min_requests=12,
                             min_dwell_s=0.02, weight_quantum=0.25)

    def _drift_trace(self, sol, flips=2):
        mm = sol.multi
        lam = mm.mix_rate * 0.6
        w = {a.model: a.weight for a in mm.assignments}
        a_name, b_name = sorted(w)
        hot = {a_name: 0.85, b_name: 0.15}
        cold = {a_name: 0.15, b_name: 0.85}
        total = lam * sum(w.values())
        phases = []
        for i in range(flips + 1):
            mix = hot if i % 2 == 0 else cold
            phases.append(({m: total * s for m, s in mix.items()}, 0.12))
        return phased_trace(phases, seed=0)

    def test_drift_triggers_resolve_and_cache_hits(self, co16):
        cache = scope.SolutionCache()
        # Solve the base deployment THROUGH the cache (the bench/CLI flow):
        # the returned Solution must keep its cost-free problem identity so
        # autoscale re-solves derived from it take the cached path.
        sol = cache.solve(co16.problem)
        assert sol.problem.options.cost is None
        trace = self._drift_trace(sol, flips=3)
        rep = sol.serve(trace=trace, autoscale=self.POLICY, cache=cache,
                        max_delay_s=5e-4)
        assert rep.conserved
        events = rep.autoscale["events"]
        assert len(events) >= 2, "mix flips must trigger re-solves"
        for e in events:
            assert e["drift"] >= self.POLICY.drift_threshold
            assert e["redeploy_s"] > 0          # switch-cost event charged
        # a mix seen before is a whole-solution cache hit
        assert any(e["cache_hit"] for e in events[1:])
        stats = rep.autoscale["solve_cache"]
        assert stats["solution_hits"] >= 1
        assert stats["engines"] == 1            # one shared FastCostModel

    def test_no_resolve_without_drift(self, co16):
        rep = co16.serve(n_requests=300, seed=0, autoscale=self.POLICY)
        assert rep.autoscale["events"] == []
        assert rep.autoscale["checks"] > 0

    def test_autoscale_requires_multimodel(self):
        sol = scope.solve(scope.problem("resnet18", "mcm16", m_samples=16))
        with pytest.raises(ValueError, match="multi-model"):
            sol.serve(n_requests=50, autoscale=True)


class TestSolveMany:
    def test_duplicate_problems_hit_cache(self):
        probs = [
            scope.problem("alexnet:1,resnet18:1", "mcm16", m_samples=16),
            scope.problem("alexnet:1,resnet18:1", "mcm16", m_samples=16),
            scope.problem("alexnet:2,resnet18:1", "mcm16", m_samples=16),
        ]
        cache = scope.SolutionCache()
        sols = scope.solve_many(probs, cache=cache)
        assert sols[0] is sols[1]               # whole-solution hit
        assert sols[2] is not sols[0]
        assert cache.stats == {
            "solution_hits": 1, "solution_misses": 2,
            "solutions": 2, "engines": 1,
        }
        # the shared engine memo makes the second distinct solve cheaper:
        # it answers evaluations without recomputing cluster costs
        stats = sols[2].diagnostics["engine_stats"]
        assert stats["segment_evals"] > 3 * stats["cluster_computes"]

    def test_fingerprint_distinguishes_options_and_weights(self):
        base = scope.problem("alexnet:1,resnet18:1", "mcm16")
        fp = scope.problem_fingerprint
        assert fp(base) == fp(scope.problem("alexnet:1,resnet18:1", "mcm16"))
        assert fp(base) != fp(scope.problem("alexnet:2,resnet18:1", "mcm16"))
        assert fp(base) != fp(base.with_options(step=2))
        assert fp(base) != fp(
            scope.problem("alexnet:1,resnet18:1", "mcm64"))

    def test_fingerprint_distinguishes_hw_perf_and_flavor_caps(self):
        """Hardware differing only in a perf field (same name/chips) or in
        PackageSpec.flavor_caps must not collide in the cache."""
        hw = get_hw("mcm16")
        slower = replace(hw, flops_per_chip=hw.flops_per_chip / 4)
        fp = scope.problem_fingerprint
        assert fp(scope.problem("resnet18", hw)) != \
            fp(scope.problem("resnet18", slower))
        cache = scope.SolutionCache()
        fast_sol = cache.solve(scope.problem("resnet18", hw))
        slow_sol = cache.solve(scope.problem("resnet18", slower))
        assert not cache.last_hit
        assert slow_sol.latency > fast_sol.latency
        het = scope.PackageSpec.of("mcm16_hetero")
        capped = replace(het, flavor_caps=(("big", 4), ("little", 4)))
        assert fp(scope.Problem(scope.WorkloadSpec.cnn("resnet18"), het)) != \
            fp(scope.Problem(scope.WorkloadSpec.cnn("resnet18"), capped))

    def test_shared_cost_rejected_on_wrong_hw(self):
        hw16 = scope.PackageSpec.of("mcm16").resolve()
        shared = scope.SearchOptions(m_samples=16).make_cost(hw16)
        with pytest.raises(ValueError, match="wrong hardware"):
            scope.solve(scope.problem("alexnet", "mcm64", cost=shared))


# ---------------------------------------------------------------------------
# Deployment / build_multimodel_steps edge cases (the executor's inputs)
# ---------------------------------------------------------------------------

class TestDeploymentEdgeCases:
    @pytest.fixture(scope="class")
    def lm_setup(self):
        from repro.configs import get_smoke_config
        from repro.core.hw import tpu_v5e

        cfgs = (get_smoke_config("granite-3-8b"),
                get_smoke_config("granite-20b"))
        return cfgs, tpu_v5e(8, (1, 8))

    def test_single_model_multimodel_plan(self, lm_setup):
        from repro.runtime.planner import plan_for_multimodel

        cfgs, hw = lm_setup
        mm, plans = plan_for_multimodel(
            [cfgs[0]], 64, 8, ("data", "model"), model_axis=8, hw=hw,
        )
        assert mm is not None and mm.n_models == 1
        assert set(plans) == {cfgs[0].name}
        plan = plans[cfgs[0].name]
        assert plan.meta["quota_chips"] <= 8
        assert plan.meta["co_mode"] == "partitioned"

    def test_zero_quota_idle_chips_assignment(self, lm_setup):
        """An assignment may use fewer chips than the axis (idle chips, the
        curves' monotone-envelope case): plans must still build and the
        executor must still serve it."""
        from repro.runtime.planner import plan_for_multimodel

        cfgs, hw = lm_setup
        mm, plans = plan_for_multimodel(
            [cfgs[0]], 64, 8, ("data", "model"), model_axis=8, hw=hw,
        )
        a = mm.assignments[0]
        idle = replace(
            mm,
            assignments=(replace(a, chips=max(1, a.chips // 2),
                                 schedule=a.schedule),),
        )
        mm2, plans2 = plan_for_multimodel(
            [cfgs[0]], 64, 8, ("data", "model"), model_axis=8, hw=hw,
            mm=idle,
        )
        assert mm2 is idle
        assert plans2[cfgs[0].name].meta["quota_chips"] == idle.assignments[0].chips
        ex = ServingExecutor(idle, hw, seed=0)
        served = idle.assignments[0]             # keyed by the graph name
        lam = max(1.0, served.throughput * 0.5)
        rep = ex.run(request_trace({served.model: lam},
                                   min(0.5, 50 / lam), seed=0))
        assert rep.conserved

    def test_time_mux_switch_cost_plans(self, lm_setup):
        """switch_cost=True time-mux co-schedules must thread gross shares
        and reloads into plans and the executor's slice windows."""
        from repro.core.fastcost import FastCostModel
        from repro.multimodel import ModelSpec
        from repro.multimodel.baselines import time_multiplexed
        from repro.core.workloads.lm import lm_graph
        from repro.runtime.planner import plan_for_multimodel

        cfgs, hw = lm_setup
        graphs = [lm_graph(c, 64, decode=False) for c in cfgs]
        specs = [ModelSpec(g, w) for g, w in zip(graphs, [2.0, 1.0])]
        cost = FastCostModel(hw, m_samples=8)
        mm = time_multiplexed(specs, cost, switch_cost=True,
                              switch_period_s=0.5)
        assert mm is not None and mm.mode == MM_TIME_MUX
        assert mm.meta["switch_cost"] and sum(mm.meta["reload_s"]) > 0
        mm2, plans = plan_for_multimodel(
            list(cfgs), 64, 8, ("data", "model"), model_axis=8, hw=hw,
            mm=mm,
        )
        assert mm2 is mm
        for cfg in cfgs:
            assert plans[cfg.name].meta["time_share"] < 1.0
        ex = ServingExecutor(mm, hw, seed=0)
        for srv in ex.servers.values():
            assert srv.window is not None and srv.window[2] == 0.5

    @pytest.mark.slow
    def test_single_model_plan_builds_jitted_steps(self, lm_setup):
        import jax

        from repro.launch.mesh import make_mesh
        from repro.models import init_params
        from repro.runtime.planner import plan_for_multimodel
        from repro.runtime.serve import build_multimodel_steps

        cfgs, hw = lm_setup
        _, plans = plan_for_multimodel(
            [cfgs[0]], 64, 8, ("data", "model"), model_axis=8, hw=hw,
        )
        mesh = make_mesh((1, len(jax.devices())), ("data", "model"))
        fleet = build_multimodel_steps([cfgs[0]], mesh, plans,
                                       with_decode=False)
        import jax.numpy as jnp

        params = init_params(cfgs[0], jax.random.PRNGKey(0))
        logits = fleet[cfgs[0].name]["prefill"](params,
                                               jnp.ones((2, 16), jnp.int32))
        assert logits.shape[0] == 2
