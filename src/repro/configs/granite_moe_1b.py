"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) expert
d_ff=512, vocab=49155, MoE 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    moe=MoEConfig(n_experts=32, top_k=8, every=1, capacity_factor=1.25),
    ffn_gated=True,
    rope_theta=10_000.0,
)
