"""Cluster Merge Table (paper Alg. 1, ``GenCMT``).

Start with every layer as its own cluster (N_cluster = L).  Repeatedly merge
the adjacent pair of clusters whose *parallelism* is most similar
(``parallelOffset = |parallel[:-1] / parallel[1:] - 1|``), recording the
clustering for every N_cluster.  One pass yields cluster divisions for all
N_cluster in 1..L -- this is the exponential->linear reduction for the
cluster dimension.

Cluster parallelism = geometric mean of its layers' ``parallel_metric``
(output pixels for convs, tokens for LM layers): layers sharing a region
should split along similar dimensions to keep the region utilized.
"""
from __future__ import annotations

from .graph import LayerGraph, geomean

# A clustering is a tuple of (lo, hi) half-open layer index ranges.
Clustering = tuple[tuple[int, int], ...]


def cluster_parallelism(graph: LayerGraph, lo: int, hi: int) -> float:
    return geomean([graph.layers[i].parallel_metric for i in range(lo, hi)])


def gen_cmt(graph: LayerGraph) -> dict[int, Clustering]:
    """Build the CMT for a (sub)graph: {N_cluster: clustering}."""
    L = len(graph)
    current: list[tuple[int, int]] = [(i, i + 1) for i in range(L)]
    cmt: dict[int, Clustering] = {L: tuple(current)}
    parallel = [cluster_parallelism(graph, lo, hi) for lo, hi in current]
    for n_cluster in range(L, 1, -1):
        # offset between adjacent clusters
        best_idx, best_off = 0, float("inf")
        for i in range(len(current) - 1):
            off = abs(parallel[i] / max(parallel[i + 1], 1e-30) - 1.0)
            if off < best_off:
                best_off, best_idx = off, i
        lo, _ = current[best_idx]
        _, hi = current[best_idx + 1]
        current[best_idx : best_idx + 2] = [(lo, hi)]
        parallel[best_idx : best_idx + 2] = [cluster_parallelism(graph, lo, hi)]
        cmt[n_cluster - 1] = tuple(current)
    return cmt


def validate_clustering(clustering: Clustering, n_layers: int) -> bool:
    cursor = 0
    for lo, hi in clustering:
        if lo != cursor or hi <= lo:
            return False
        cursor = hi
    return cursor == n_layers
