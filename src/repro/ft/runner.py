"""Fault-tolerant training driver.

At 1000+ nodes, *something* is always failing; the framework treats failure
as the normal path:

* **checkpoint/restart** -- atomic checkpoints every ``ckpt_every`` steps;
  on any step exception the trainer restores the latest checkpoint and
  replays (the data pipeline is (seed, step)-deterministic, so replays are
  bit-consistent).
* **bounded retries** -- repeated failure of the same step aborts rather
  than loops (poison-step detection).
* **straggler mitigation** -- per-step wall times feed a rolling median;
  steps slower than ``k x median`` are flagged; the policy hook decides
  (log / re-dispatch / drop the slow replica from the next allocation).
  On a real cluster this drives the scheduler; here the policy is pluggable
  and unit-tested with injected delays.
* **elastic rescale** -- restore accepts a different mesh than the one that
  wrote the checkpoint (ckpt/checkpoint.py stores unsharded leaves), so a
  restart may proceed with fewer/more pods.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from statistics import median

from ..ckpt import latest_step, restore_checkpoint, save_checkpoint
from ..ckpt.checkpoint import prune_checkpoints

log = logging.getLogger("repro.ft")


@dataclass
class StragglerMonitor:
    threshold: float = 2.0       # k x median => straggler
    window: int = 32
    times: list = field(default_factory=list)
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) >= 5:
            med = median(self.times)
            if dt > self.threshold * med:
                self.flagged.append((step, dt, med))
                return True
        return False


@dataclass
class ResilientTrainer:
    train_step: callable         # (params, opt, batch) -> (params, opt, metrics)
    batch_fn: callable           # step -> batch  (deterministic)
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_retries_per_step: int = 3
    straggler: StragglerMonitor = field(default_factory=StragglerMonitor)
    on_straggler: callable = None

    def run(self, params, opt_state, n_steps: int, start_step: int = 0,
            shardings=None, failure_injector=None):
        """Runs to ``n_steps``; returns (params, opt_state, history).

        ``failure_injector`` is either a ``(step) -> None`` callable that
        raises to simulate a failure, or a
        :class:`repro.serving.faults.FaultInjector` -- the serving
        simulator's seeded chaos source -- whose failure windows are
        mapped onto step indices (1 step = 1 simulated second), so the
        trainer and the serving executor share one deterministic fault
        vocabulary.
        """
        if failure_injector is not None and hasattr(failure_injector,
                                                    "step_hook"):
            failure_injector = failure_injector.step_hook(n_steps=n_steps)
        step = start_step
        # resume if a checkpoint exists
        last = latest_step(self.ckpt_dir)
        if last is not None and last > step:
            (params, opt_state), manifest = restore_checkpoint(
                self.ckpt_dir, last, (params, opt_state), shardings
            )
            step = manifest["step"]
            log.info("resumed from checkpoint step %d", step)
        history = []
        # Retries are tracked per step index: after a restore, failures on
        # *different* replayed steps are distinct incidents, not one poison
        # step -- a single shared counter would abort N transient faults on
        # N distinct steps as a false poison step.
        retries: dict[int, int] = {}
        while step < n_steps:
            batch = self.batch_fn(step)
            t0 = time.monotonic()
            try:
                if failure_injector is not None:
                    failure_injector(step)
                params, opt_state, metrics = self.train_step(params, opt_state, batch)
                loss = float(metrics["loss"])
            except Exception as exc:  # noqa: BLE001 -- restart-on-anything
                failed = step
                retries[failed] = retries.get(failed, 0) + 1
                if retries[failed] > self.max_retries_per_step:
                    raise RuntimeError(
                        f"step {failed} failed {retries[failed]}x"
                    ) from exc
                last = latest_step(self.ckpt_dir)
                if last is not None:
                    (params, opt_state), manifest = restore_checkpoint(
                        self.ckpt_dir, last, (params, opt_state), shardings
                    )
                    step = manifest["step"]
                    log.warning("step failed (%s); restored step %d", exc, step)
                else:
                    log.warning("step failed (%s); no checkpoint, retrying", exc)
                continue
            dt = time.monotonic() - t0
            if self.straggler.observe(step, dt) and self.on_straggler:
                self.on_straggler(step, dt)
            retries.pop(step, None)
            step += 1
            history.append({"step": step, "loss": loss, "time": dt})
            if step % self.ckpt_every == 0:
                save_checkpoint(self.ckpt_dir, step, (params, opt_state),
                                meta={"loss": loss})
                prune_checkpoints(self.ckpt_dir, self.keep)
        return params, opt_state, history
