"""Fig. 11 (beyond-paper): multi-model co-scheduling vs static baselines.

For each traffic mix, compares the co-scheduler's weighted throughput (best
of partitioned quotas / merged pipeline / time-mux, facade strategy
``coschedule``) against the two static baselines (facade strategies
``equal-split`` and ``time-mux``).  The co-scheduler searches a superset of
both baseline families, so it must be >= each of them on every mix --
asserted here.

The last mixes run on a heterogeneous big/little package (the hetero-chiplet
extension): quotas are drawn per chip flavor, the engine memo keeps the
flavors' cluster costs apart, and quotas may *span* flavors (mixed-flavor
pipelines: ``partitioned:mixed`` in the mode rates).  On hetero rows the
mixed-enabled co-schedule must be >= the single-flavor partitioned family,
and every winning schedule is re-evaluated on the reference CostModel
(``Solution.verify_reference``) to assert fast/reference parity on
mixed-flavor candidates.
"""
from __future__ import annotations

from repro import scope

from .common import M_SAMPLES, cached

# (mix, hardware preset); the first three are the acceptance mixes, the
# last two exercise the heterogeneous package (the final one is the
# big/little mix where spanning quotas must win strictly: resnet50 carries
# most of the traffic, so giving it one whole flavor is not enough).
MIXES = [
    ("resnet50:1,alexnet:1", "mcm16"),
    ("resnet152:1,resnet18:1", "mcm64"),
    ("resnet50:2,resnet18:1,alexnet:1", "mcm64"),
    ("resnet50:1,resnet18:1", "mcm64_hetero"),
    ("resnet50:4,resnet18:1", "mcm64_hetero"),
]


def _slug(mix: str, hw: str) -> str:
    return f"fig11_{mix.replace(':', '').replace(',', '_')}_{hw}"


def run_mix(mix: str, hw_name: str) -> dict:
    prob = scope.problem(mix, hw_name, m_samples=M_SAMPLES,
                         strategy="coschedule")
    sol = scope.solve(prob)
    co = sol.multi
    if co is None:
        return {"mix": mix, "hw": hw_name, "chips": sol.hw.chips,
                "co_mode": "infeasible", "co_weighted_throughput": 0.0,
                "equal_split_weighted_throughput": 0.0,
                "time_mux_weighted_throughput": 0.0,
                "co_search_s": sol.diagnostics["dse_s"]}
    row = {
        "mix": mix,
        "hw": hw_name,
        "chips": sol.hw.chips,
        "weights": [m.weight for m in prob.workload.models],
        "co_weighted_throughput": co.weighted_throughput,
        "co_mode": co.mode,
        "co_mix_rate": co.mix_rate,
        "co_search_s": sol.diagnostics["dse_s"],
        "co_assignments": [
            {
                "model": a.model, "chips": a.chips, "chip_type": a.chip_type,
                "chip_quota": [[t, c] for t, c in a.chip_quota],
                "throughput": a.throughput, "time_share": a.time_share,
                "samples_per_beat": a.samples_per_beat,
            }
            for a in co.assignments
        ],
        "mode_rates": sol.diagnostics["mode_rates"],
        "engine_stats": sol.diagnostics["engine_stats"],
        "seam_crossings": sol.diagnostics.get("seam_crossings", {}),
    }
    if sol.hw.region_types:
        # Hetero rows: the mixed-enabled search must not lose to the
        # single-flavor quota family it strictly generalizes...
        single = sol.diagnostics["mode_rates"].get("partitioned", 0.0)
        assert co.weighted_throughput >= single - 1e-9, (mix, hw_name)
        row["single_flavor_partitioned_throughput"] = single
        row["mixed_wins"] = (
            sol.diagnostics["mode_rates"].get("partitioned:mixed", 0.0)
            > single
        )
        # ...and the winning schedules (spanning ones included) must
        # evaluate identically on the reference model.
        sol.verify_reference()
        row["mixed_parity_checked"] = True
    eq = scope.solve(prob.with_options(strategy="equal-split"))
    row["equal_split_weighted_throughput"] = (
        eq.weighted_throughput if eq.feasible else 0.0
    )
    # time-mux is one of co_schedule's searched modes: reuse its rate
    # instead of re-running the per-model full-package searches.
    row["time_mux_weighted_throughput"] = sol.diagnostics["mode_rates"].get(
        "time_mux", 0.0
    )
    return row


def run(refresh: bool = False, mixes=None) -> list[dict]:
    rows = []
    for mix, hw_name in mixes or MIXES:
        rows.append(cached(_slug(mix, hw_name),
                           lambda mix=mix, hw=hw_name: run_mix(mix, hw),
                           refresh))
    return rows


def report(rows) -> list[str]:
    lines = ["mix,hw,co_mode,co_tp,equal_split_tp,time_mux_tp,"
             "vs_equal,vs_timemux"]
    all_ok = True
    for r in rows:
        co = r["co_weighted_throughput"]
        eq = r["equal_split_weighted_throughput"]
        tm = r["time_mux_weighted_throughput"]
        ok = co >= eq - 1e-9 and co >= tm - 1e-9
        all_ok &= ok
        lines.append(
            f"{r['mix']},{r['hw']},{r['co_mode']},{co:.0f},{eq:.0f},{tm:.0f},"
            f"{co / eq if eq else float('inf'):.2f}x,"
            f"{co / tm if tm else float('inf'):.2f}x"
        )
    lines.append(f"# co-scheduler >= both baselines on every mix: {all_ok}")
    assert all_ok, "co-scheduler fell below a static baseline"
    return lines
