"""Deterministic synthetic LM data pipeline.

Produces learnable structure (a noisy Markov chain over the vocab) rather
than uniform noise, so end-to-end training examples show a real loss drop.
Host-side NumPy, deterministic per (seed, step): a restart resumes the
stream exactly (checkpoint stores only the step counter), which is what
makes the fault-tolerance story exact-restart (ft/runner.py).
"""
from __future__ import annotations

import numpy as np


class SyntheticLM:
    """Noisy-Markov token stream: token_{t+1} = (a * token_t + b) % V with
    probability (1-noise), else uniform."""

    def __init__(self, vocab: int, seed: int = 0, noise: float = 0.1):
        self.vocab = vocab
        self.seed = seed
        self.noise = noise
        self.a = 31 if vocab > 31 else 3
        self.b = 7

    def batch(self, step: int, batch: int, seq: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch)
        noise_mask = rng.random((batch, seq)) < self.noise
        noise_tok = rng.integers(0, self.vocab, (batch, seq))
        for t in range(seq):
            nxt = (self.a * toks[:, t] + self.b) % self.vocab
            toks[:, t + 1] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def frontend_batch(self, step: int, batch: int, seq: int, d_model: int,
                       frontend_tokens: int = 0) -> dict:
        """Batch for stub-frontend archs: embeddings + (optional) text tokens."""
        out = self.batch(step, batch, seq)
        rng = np.random.default_rng((self.seed, step, 1))
        if frontend_tokens:          # vision: patches ahead of text
            out["tokens"] = out["tokens"][:, : seq - frontend_tokens]
            emb = rng.standard_normal((batch, frontend_tokens, d_model), np.float32)
        else:                        # audio: every position is a frame embed
            out.pop("tokens")
            emb = rng.standard_normal((batch, seq, d_model), np.float32)
        out["frontend_embeds"] = emb * 0.02
        return out


def make_batch_iterator(cfg, batch: int, seq: int, seed: int = 0, start_step: int = 0):
    """Infinite iterator of jnp-ready batches for an arch config."""
    src = SyntheticLM(cfg.vocab, seed=seed)
    step = start_step
    while True:
        if cfg.frontend == "none":
            yield step, src.batch(step, batch, seq)
        else:
            yield step, src.frontend_batch(
                step, batch, seq, cfg.d_model, cfg.frontend_tokens
            )
        step += 1
